"""ClusterEngine: one dispatch layer for every co-clustering solve.

Mirrors repro.embedding.EmbeddingEngine: a registry of solvers behind
one ``solve()`` API so the clustering hot path can be swapped,
benchmarked and sharded without touching call sites. launch/, serve/,
benchmarks/ and examples/ construct a ClusterEngine; only core/ ever
imports a solver module directly (tests/test_cluster_engine.py greps
for violations).

Solvers:

  * "jax"          device-resident side-synchronous LP: the whole
                   iteration loop is a lax.while_loop (convergence +
                   budget checked on-device), with a vmap-batched grid
                   mode used by fit_gamma(batched=True).
  * "jax_sharded"  the same math edge-partitioned over a 1-D device
                   mesh via shard_map (repro.distributed.sharding):
                   local segment sums + one psum of the per-label
                   weight totals. Matches "jax" label-for-label on the
                   tested meshes (the psum reassociates f32 weight
                   sums, so only a last-ulp score tie could diverge —
                   see solver_sharded).
  * "numpy"        the paper-faithful sequential Algorithm 1 sweep.
  * "jax_hostloop" the pre-engine host-driven loop (one dispatch and a
                   full labels transfer per sweep); never auto-selected,
                   kept as the benchmark/bit-for-bit reference.
  * "jax_streamed" the edge-block streamed solve (solver_jax.
                   lp_solve_streamed): edges stay host-side and sweep
                   through one compiled per-block program, bit-for-bit
                   equal to "jax" with O(nodes + block) device
                   residency — the million-node path. Never
                   auto-selected (the in-memory solver is faster when
                   the graph fits); size via ClusterEngine(block_edges=
                   ...), telemetry on the solver's ``last_stats``.

Auto-selection (solver=None/"auto"): "jax_sharded" when a mesh is given
or more than one device is visible, else "jax".

ClusterEngine also carries the ``candidates`` knob ("exact" default |
"minhash"): the stream layer's cold-assign and refresh read it to prune
per-node candidate labels through core.candidates (minhash bucket
nomination). It lives here so call sites configure ONE engine object,
but engine.solve() itself is always exact — pruning is an explicit
opt-in of the assignment paths that measure their recall.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs.trace import get_tracer

from .graph import BipartiteGraph
from .sketch import Sketch, compact_labels
from .weights import make_weights

__all__ = ["ClusterEngine", "ClusterSolver", "register_solver",
           "get_solver", "available_solvers", "normalize_solver"]


# ---------------------------------------------------------------------------
# solver registry
# ---------------------------------------------------------------------------
class ClusterSolver:
    """One co-clustering solve strategy. Subclass + register.

    Contract: solve() returns (labels int32[n_nodes] in the shared id
    space, iters_run); labels are NOT compacted. solve_many() solves a
    gamma grid with one shared (or absent) warm-start seed and returns
    (labels [L, n_nodes], iters [L]).
    """
    name: str = "?"
    batched_grid: bool = False    # solve_many runs lanes concurrently
    accepts_mesh: bool = False    # solve(..., mesh=) is meaningful
    accepts_block_edges: bool = False   # solve(..., block_edges=) meaningful
    auto_eligible: bool = True    # may be picked by auto-selection

    def solve(self, graph: BipartiteGraph, wu, wv, gamma: float,
              budget: Optional[int] = None, max_iters: int = 8,
              init_labels=None, *, mesh=None) -> Tuple[np.ndarray, int]:
        raise NotImplementedError

    def solve_many(self, graph, wu, wv, gammas, budget=None, max_iters=8,
                   init_labels=None, *, mesh=None):
        init = None if init_labels is None else np.asarray(init_labels)
        labs, its = [], []
        for i, g in enumerate(gammas):
            seed = init[i] if init is not None and init.ndim == 2 else init
            lab, it = self.solve(graph, wu, wv, float(g), budget, max_iters,
                                 seed, mesh=mesh)
            labs.append(lab)
            its.append(it)
        return np.stack(labs), np.asarray(its, np.int32)

    def secondary(self, graph, labels, wu, wv, gamma: float) -> np.ndarray:
        """Secondary (runner-up) user assignment — SCU, Alg. 2 line 18."""
        return _secondary_jax(graph, labels, wu, wv, gamma)


_REGISTRY: Dict[str, ClusterSolver] = {}


def register_solver(solver: ClusterSolver) -> ClusterSolver:
    _REGISTRY[solver.name] = solver
    return solver


def get_solver(name: str) -> ClusterSolver:
    if name not in _REGISTRY:
        raise KeyError(f"unknown cluster solver {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_solvers():
    return tuple(sorted(_REGISTRY))


def normalize_solver(name: Optional[str]) -> Optional[str]:
    """None/"auto" -> None (auto-selection); else must be registered."""
    if name is None or name == "auto":
        return None
    get_solver(name)
    return name


class _JaxSolver(ClusterSolver):
    name = "jax"
    batched_grid = True

    def solve(self, graph, wu, wv, gamma, budget=None, max_iters=8,
              init_labels=None, *, mesh=None):
        from . import solver_jax
        return solver_jax.lp_solve(graph, wu, wv, gamma, budget, max_iters,
                                   init_labels=init_labels)

    def solve_many(self, graph, wu, wv, gammas, budget=None, max_iters=8,
                   init_labels=None, *, mesh=None):
        from . import solver_jax
        return solver_jax.lp_solve_grid(graph, wu, wv, gammas, budget,
                                        max_iters, init_labels=init_labels)


class _JaxHostloopSolver(ClusterSolver):
    name = "jax_hostloop"
    auto_eligible = False

    def solve(self, graph, wu, wv, gamma, budget=None, max_iters=8,
              init_labels=None, *, mesh=None):
        from . import solver_jax
        return solver_jax.lp_solve_hostloop(graph, wu, wv, gamma, budget,
                                            max_iters,
                                            init_labels=init_labels)


class _ShardedSolver(ClusterSolver):
    name = "jax_sharded"
    accepts_mesh = True

    def solve(self, graph, wu, wv, gamma, budget=None, max_iters=8,
              init_labels=None, *, mesh=None):
        from . import solver_sharded
        return solver_sharded.lp_solve_sharded(graph, wu, wv, gamma, budget,
                                               max_iters,
                                               init_labels=init_labels,
                                               mesh=mesh)


class _StreamedSolver(ClusterSolver):
    name = "jax_streamed"
    accepts_block_edges = True
    auto_eligible = False     # in-memory "jax" wins whenever edges fit

    def __init__(self):
        # sweep telemetry of the most recent solve (blocks, per-sweep
        # seconds, peak device bytes) — how benchmarks read the streamed
        # path's numbers without importing the solver module directly
        self.last_stats: dict = {}

    def solve(self, graph, wu, wv, gamma, budget=None, max_iters=8,
              init_labels=None, *, mesh=None, block_edges=None):
        from . import solver_jax
        stats: dict = {}
        out = solver_jax.lp_solve_streamed(
            graph, wu, wv, gamma, budget, max_iters,
            init_labels=init_labels,
            block_edges=int(block_edges) if block_edges else 1 << 20,
            stats=stats)
        self.last_stats = stats
        return out


class _NumpySolver(ClusterSolver):
    name = "numpy"
    auto_eligible = False     # paper-faithful reference, orders slower

    def solve(self, graph, wu, wv, gamma, budget=None, max_iters=8,
              init_labels=None, *, mesh=None):
        from . import solver_numpy
        return solver_numpy.lp_solve_sequential(graph, wu, wv, gamma, budget,
                                                max_iters,
                                                init_labels=init_labels)

    def secondary(self, graph, labels, wu, wv, gamma):
        return _secondary_numpy(graph, labels, wu, wv, gamma)


register_solver(_JaxSolver())
register_solver(_JaxHostloopSolver())
register_solver(_ShardedSolver())
register_solver(_StreamedSolver())
register_solver(_NumpySolver())


# ---------------------------------------------------------------------------
# device-side partition scoring (one pass for the whole gamma grid)
# ---------------------------------------------------------------------------
def _score_partitions(graph: BipartiteGraph, labels_batch: np.ndarray):
    """(k = ku+kv, Barber modularity) for a batch of partitions in ONE
    device pass — fit_gamma's grid is scored without per-grid-point host
    modularity recomputation. f32 on device; the same scorer is used for
    both the sequential and batched grid so selection ties break
    identically."""
    import jax.numpy as jnp
    du = np.asarray(graph.user_degrees(), np.float32)
    dv = np.asarray(graph.item_degrees(), np.float32)
    ks, qs = _score_jit(jnp.asarray(labels_batch), jnp.asarray(graph.edge_u),
                        jnp.asarray(graph.edge_v), jnp.asarray(du),
                        jnp.asarray(dv), n_users=graph.n_users,
                        n_items=graph.n_items)
    return np.asarray(ks), np.asarray(qs)


@functools.cache
def _score_jit_factory():
    import jax

    @functools.partial(jax.jit, static_argnames=("n_users", "n_items"))
    def score(labels_b, eu, ev, du, dv, *, n_users, n_items):
        import jax.numpy as jnp
        from .solver_jax import _count_side
        n = n_users + n_items
        e = max(int(eu.shape[0]), 1)

        def one(lab):
            lu, lv = lab[:n_users], lab[n_users:]
            intra = jnp.sum(lu[eu] == lv[ev]).astype(jnp.float32)
            du_k = jax.ops.segment_sum(du, lu, num_segments=n)
            dv_k = jax.ops.segment_sum(dv, lv, num_segments=n)
            q = (intra - du_k @ dv_k / e) / e
            ku, kv = _count_side(lab, n_users, n_items)
            return ku + kv, q

        return jax.vmap(one)(labels_b)

    return score


def _score_jit(*args, **kw):
    return _score_jit_factory()(*args, **kw)


# ---------------------------------------------------------------------------
# SCU secondary assignment (solver-keyed implementations)
# ---------------------------------------------------------------------------
def _secondary_numpy(graph: BipartiteGraph, labels, wu, wv, gamma):
    lab = labels.astype(np.int64).copy()
    nu = graph.n_users
    u_indptr, u_nbrs = graph.user_csr()
    n = graph.n_nodes
    w_v_by_label = np.bincount(lab[nu:], weights=wv, minlength=n)
    out = lab[:nu].copy()
    for i in range(nu):
        nbrs = u_nbrs[u_indptr[i]:u_indptr[i + 1]]
        if nbrs.size == 0:
            continue
        cand, cnt = np.unique(lab[nu + nbrs], return_counts=True)
        own = lab[i]
        keep = cand != own
        if not keep.any():
            continue
        scores = (cnt - gamma * wu[i] * w_v_by_label[cand])[keep]
        out[i] = cand[keep][int(np.argmax(scores))]
    return out.astype(np.int32)


def _secondary_jax(graph: BipartiteGraph, labels, wu, wv, gamma):
    import jax
    import jax.numpy as jnp
    nu, n = graph.n_users, graph.n_nodes
    lab = jnp.asarray(labels, jnp.int32)
    own = lab[:nu]
    item_labels = lab[nu:]
    wv_by_label = jax.ops.segment_sum(jnp.asarray(wv, jnp.float32),
                                      item_labels, num_segments=n)
    eu = jnp.asarray(graph.edge_u)
    cand_lab = item_labels[jnp.asarray(graph.edge_v)]
    # group (user, label) pairs as in the solver, then argmax w/o primary
    node_s, lab_s = jax.lax.sort((eu, cand_lab), num_keys=2)
    e = node_s.shape[0]
    new_grp = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (node_s[1:] != node_s[:-1]) | (lab_s[1:] != lab_s[:-1])])
    gid = jnp.cumsum(new_grp.astype(jnp.int32)) - 1
    cnt = jax.ops.segment_sum(jnp.ones((e,), jnp.float32), gid,
                              num_segments=e, indices_are_sorted=True)[gid]
    wu_j = jnp.asarray(wu, jnp.float32)
    score = cnt - jnp.float32(gamma) * wu_j[node_s] * wv_by_label[lab_s]
    score = jnp.where(lab_s == own[node_s], -3e38, score)   # exclude primary
    best = jax.ops.segment_max(score, node_s, num_segments=nu,
                               indices_are_sorted=True)
    best = jnp.where(jnp.isfinite(best), best, -3e38)
    is_best = (score >= best[node_s]) & (score > -3e38)
    cand = jnp.where(is_best, lab_s, jnp.int32(n))
    best_lab = jax.ops.segment_min(cand, node_s, num_segments=nu,
                                   indices_are_sorted=True)
    has = best_lab < n
    return np.asarray(jnp.where(has, best_lab, own).astype(jnp.int32))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ClusterEngine:
    """Routes co-clustering work through the selected solver.

    solver: explicit override ("jax" | "jax_sharded" | "numpy" |
            "jax_hostloop" | "jax_streamed" | None/"auto").
    mesh:   1-D device mesh for "jax_sharded" (defaults to every local
            device); passing a mesh also steers auto-selection to the
            sharded solver.
    candidates: "exact" (default) scores every neighbor label;
            "minhash" lets the stream layer's cold-assign/refresh prune
            per-node candidates via core.candidates (engine.solve()
            itself is always exact).
    block_edges: nominal edges per streamed block for "jax_streamed"
            (node-aligned; any value is bit-for-bit exact — it only
            trades dispatches against per-block memory).
    """
    solver: Optional[str] = None
    mesh: object = None
    candidates: str = "exact"
    block_edges: Optional[int] = None

    def __post_init__(self):
        if self.candidates not in ("exact", "minhash"):
            raise ValueError(f"candidates must be 'exact'|'minhash', "
                             f"got {self.candidates!r}")
        if self.block_edges is not None and int(self.block_edges) <= 0:
            raise ValueError("block_edges must be positive")

    def resolve(self) -> ClusterSolver:
        if self.solver is not None and self.solver != "auto":
            return get_solver(self.solver)
        if self.mesh is not None:
            return get_solver("jax_sharded")
        import jax
        if jax.device_count() > 1:
            return get_solver("jax_sharded")
        return get_solver("jax")

    def _mesh_kw(self, solver: ClusterSolver) -> dict:
        kw = {"mesh": self.mesh} if solver.accepts_mesh else {}
        if solver.accepts_block_edges and self.block_edges:
            kw["block_edges"] = int(self.block_edges)
        return kw

    # -- one solve ---------------------------------------------------------
    def solve(self, graph: BipartiteGraph, wu, wv, gamma: float,
              budget: Optional[int] = None, max_iters: int = 8,
              init_labels=None) -> Tuple[np.ndarray, int]:
        """Run one LP solve. Returns (labels int32[n_nodes], iters)."""
        s = self.resolve()
        with get_tracer().span("cluster_solve", solver=s.name,
                               n_nodes=int(graph.n_nodes),
                               n_edges=int(graph.n_edges),
                               gamma=float(gamma)) as sp:
            labels, iters = s.solve(graph, wu, wv, gamma, budget, max_iters,
                                    init_labels, **self._mesh_kw(s))
            sp.set(iters=int(iters))
        return labels, iters

    def solve_grid(self, graph: BipartiteGraph, wu, wv, gammas,
                   budget: Optional[int] = None, max_iters: int = 8,
                   init_labels=None):
        """Solve a gamma grid (concurrent lanes when the solver batches).
        Returns (labels [L, n_nodes], iters [L])."""
        s = self.resolve()
        gammas = [float(g) for g in gammas]
        with get_tracer().span("cluster_solve_grid", solver=s.name,
                               n_nodes=int(graph.n_nodes),
                               n_gammas=len(gammas)):
            return s.solve_many(graph, wu, wv, gammas, budget, max_iters,
                                init_labels, **self._mesh_kw(s))

    # -- gamma auto-tuning -------------------------------------------------
    def fit_gamma(self, graph: BipartiteGraph, wu, wv, budget: int, *,
                  max_iters: int = 8, grid: int = 10, gamma0: float = 1.0,
                  warm_start: bool = True, batched: bool = False,
                  lanes: int = 4) -> Tuple[float, np.ndarray, int]:
        """Pick gamma on a log-grid: best bipartite modularity s.t.
        K <= budget.

        K(gamma) is NOT monotone for the side-synchronous solver
        (measured on synthetic Gowalla: K dips between gamma=4 and 16
        while quality rises), so a budget bisection can lock onto a poor
        plateau. Bipartite modularity of the resulting partition tracks
        downstream Recall@20 almost perfectly (see EXPERIMENTS.md
        §Paper-validation/gamma-proxy), and all grid partitions are
        scored in ONE device pass — so we grid-search gamma and keep the
        most-modular partition that fits the budget. Matches the paper's
        protocol of tuning gamma per dataset (Table 7) without a
        validation training run.

        warm_start: the grid is walked from the LARGEST gamma down, each
        solve seeded with the previous (finer) partition instead of
        singletons. Label propagation can only merge/relabel into
        existing neighbor labels — it never mints new ones — so warm
        starts are safe exactly in the fine->coarse direction: lowering
        gamma only asks for more merging (tests/test_warm_start.py).

        batched: solve the grid in vmapped blocks of ``lanes`` gammas
        (solvers with batched_grid; others fall back to the sequential
        walk). With warm_start, each block runs Jacobi rounds of the
        warm-start chain: round r re-solves every lane concurrently with
        lane i seeded by lane i-1's round r-1 partition (fine -> coarse,
        the only safe seeding direction), and stops at the fixed point —
        lane i is chain-exact after round i+1, so at most len(block)
        rounds reproduce the sequential walk BIT-FOR-BIT while already-
        converged lanes cost one masked sweep. Batched and sequential
        walks therefore solve identical subproblems and select
        identically (tests/test_cluster_engine.py asserts it).

        The x2-refinement probes are deduped against already-solved
        gammas before solving (defensive: with the default x4-spaced
        grid they never coincide, but a finer grid spacing must not
        double-solve).
        """
        s = self.resolve()
        if batched and not s.batched_grid:
            import warnings
            warnings.warn(
                f"cluster solver {s.name!r} has no batched grid mode; "
                f"fit_gamma falls back to the sequential walk (use "
                f"solver='jax' for vmapped lanes)", stacklevel=2)
        gammas = sorted((float(gamma0) * (4.0 ** i)
                         for i in range(-3, grid - 3)), reverse=True)
        with get_tracer().span("fit_gamma", solver=s.name,
                               n_nodes=int(graph.n_nodes),
                               budget=int(budget),
                               grid=len(gammas)) as f_sp:
            solved_g, solved_lab, solved_it = [], [], []
            if batched and s.batched_grid:
                chain_seed = None    # warm-start seed carried across blocks
                for lo in range(0, len(gammas), max(1, lanes)):
                    chunk = gammas[lo:lo + max(1, lanes)]
                    if not warm_start:
                        labs, its = s.solve_many(graph, wu, wv, chunk, budget,
                                                 max_iters, init_labels=None,
                                                 **self._mesh_kw(s))
                    else:
                        labs = its = None
                        for _ in range(len(chunk)):
                            if labs is None:       # round 1: block-wide seed
                                init = chain_seed  # (None -> singletons)
                            else:                  # lane i <- lane i-1
                                shifted = [chain_seed if chain_seed is not None
                                           else np.arange(graph.n_nodes,
                                                          dtype=np.int32)]
                                shifted += [labs[i] for i in
                                            range(len(chunk) - 1)]
                                init = np.stack(shifted)
                            new_labs, its = s.solve_many(
                                graph, wu, wv, chunk, budget, max_iters,
                                init_labels=init, **self._mesh_kw(s))
                            if labs is not None and np.array_equal(new_labs,
                                                                   labs):
                                break              # chain fixed point
                            labs = new_labs
                        chain_seed = labs[len(chunk) - 1]
                    solved_g += chunk
                    solved_lab += [labs[i] for i in range(len(chunk))]
                    solved_it += [int(its[i]) for i in range(len(chunk))]
            else:
                prev = None
                for g in gammas:
                    labels, it = self.solve(
                        graph, wu, wv, g, budget, max_iters,
                        init_labels=prev if warm_start else None)
                    if warm_start:
                        prev = labels
                    solved_g.append(g)
                    solved_lab.append(labels)
                    solved_it.append(int(it))

            ks, qs = _score_partitions(graph, np.stack(solved_lab))
            best = self._select(budget, solved_g, solved_lab, solved_it, ks, qs)
            if best is None:     # nothing within budget: closest-K fallback
                i = int(np.argmin(ks))
                f_sp.set(gamma=float(solved_g[i]), fallback=True)
                return solved_g[i], solved_lab[i], solved_it[i]

            # refinement: the grid is x4-spaced; probe the x2 neighbours,
            # skipping probes that land on an already-solved grid gamma
            q_best, g_best, lab_best, it_best = best
            probes = [g for g in (g_best * 2.0, g_best / 2.0)
                      if not any(np.isclose(g, gg, rtol=1e-6)
                                 for gg in solved_g)]
            if probes:
                p_lab, p_it = [], []
                for g in probes:
                    seed = None
                    if warm_start:
                        finer = [gg for gg in solved_g if gg > g]
                        if finer:
                            seed = solved_lab[solved_g.index(min(finer))]
                    lab, it = self.solve(graph, wu, wv, g, budget,
                                         max_iters, init_labels=seed)
                    p_lab.append(lab)
                    p_it.append(int(it))
                pks, pqs = _score_partitions(graph, np.stack(p_lab))
                ref = self._select(budget, probes, p_lab, p_it, pks, pqs)
                if ref is not None and ref[0] > q_best:
                    q_best, g_best, lab_best, it_best = ref
            f_sp.set(gamma=float(g_best))
            return g_best, lab_best, it_best

    @staticmethod
    def _select(budget, gs, labs, its, ks, qs):
        """(q, gamma, labels, iters) of the most-modular within-budget
        partition (first index on ties, matching walk order), or None."""
        best = None
        for i in range(len(gs)):
            if int(ks[i]) <= budget and (best is None or qs[i] > best[0]):
                best = (float(qs[i]), gs[i], labs[i], its[i])
        return best

    # -- SCU ---------------------------------------------------------------
    def secondary_user_labels(self, graph: BipartiteGraph, labels, wu, wv,
                              gamma: float) -> np.ndarray:
        """Secondary user clusters (Alg. 2 line 18).

        The paper reruns the user sweep once; at a converged fixed point
        that reproduces the primary labels exactly, which would make SCU
        a no-op. Matching the stated motivation ("users share taste
        similarities with various user groups") we take the RUNNER-UP
        label: the best-scoring candidate cluster other than the primary
        one (falling back to the primary for users with a single
        candidate). Recorded in DESIGN.md.
        """
        return self.resolve().secondary(graph, labels, wu, wv, gamma)

    # -- the paper's complete pipeline --------------------------------------
    def build(self, graph: BipartiteGraph, *, d: int = 64,
              budget: Optional[int] = None, ratio: float = 0.25,
              gamma: Optional[float] = None, scheme: str = "hws",
              max_iters: int = 8, scu: bool = True,
              batched_gamma: bool = False) -> Sketch:
        """Build the BACO sketch (budget handling, gamma auto-tuning,
        SCU, sketch assembly — the paper's complete pipeline).

        budget: total codebook rows K_u + K_v; defaults to
        ratio*(|U|+|V|).
        """
        if budget is None:
            budget = max(2, int(round(ratio * graph.n_nodes)))
        eff_budget = budget
        if scu:  # Alg. 2: B' = (B*d - |U|) / d
            eff_budget = max(2, int((budget * d - graph.n_users) // d))
        wu, wv = make_weights(graph, scheme)
        if gamma is None:
            gamma, labels, iters = self.fit_gamma(graph, wu, wv, eff_budget,
                                                  max_iters=max_iters,
                                                  batched=batched_gamma)
        else:
            labels, iters = self.solve(graph, wu, wv, gamma, eff_budget,
                                       max_iters)
        pu = labels[:graph.n_users]
        pv = labels[graph.n_users:]
        meta = {"gamma": float(gamma), "iters": int(iters),
                "scheme": scheme, "solver": self.resolve().name,
                "budget": int(budget), "eff_budget": int(eff_budget),
                "scu": bool(scu),
                "joint_labels": np.asarray(labels, dtype=np.int32)}
        if scu:
            su = self.secondary_user_labels(graph, labels, wu, wv, gamma)
            # raw (shared-id-space) secondary labels, for warm streaming
            # updates (repro.stream) that must keep label->row maps stable
            meta["secondary_labels"] = np.asarray(su, dtype=np.int32)
            ku, pu_c, su_c = compact_labels(pu, su)
            kv, pv_c = compact_labels(pv)
            return Sketch(np.stack([pu_c, su_c], axis=1), pv_c[:, None],
                          ku, kv, method="baco", meta=meta)
        ku, pu_c = compact_labels(pu)
        kv, pv_c = compact_labels(pv)
        return Sketch(pu_c[:, None], pv_c[:, None], ku, kv,
                      method="baco(w/o scu)", meta=meta)
