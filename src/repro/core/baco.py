"""BACO top level: budget handling, gamma auto-tuning, SCU, sketch build.

The paper fixes gamma per dataset (Table 7) so that the surviving label
count meets the codebook budget B within T iterations (Fig. 4 shows the
ratio converging in ~5 iters). We expose both modes:

  * gamma given     -> run the solver, report whatever K comes out;
  * gamma=None      -> log-grid search keeping the partition with the
                       best bipartite modularity among those fitting the
                       budget (see fit_gamma docstring for why a budget
                       bisection is unsafe).

SCU (Alg. 2): with secondary user sketches the budget is tightened to
B' = (B*d - |U|)/d, then ONE extra user half-step over the converged
labels yields the secondary assignment; primary+secondary user labels are
compacted jointly so both index one user codebook.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .graph import BipartiteGraph
from .sketch import Sketch, compact_labels
from .weights import make_weights
from . import solver_jax, solver_numpy

__all__ = ["baco_build", "fit_gamma", "secondary_user_labels"]


def _solve(graph, wu, wv, gamma, budget, max_iters, solver,
           init_labels=None):
    if solver == "jax":
        return solver_jax.lp_solve(graph, wu, wv, gamma, budget, max_iters,
                                   init_labels=init_labels)
    if solver == "numpy":
        return solver_numpy.lp_solve_sequential(graph, wu, wv, gamma, budget,
                                                max_iters,
                                                init_labels=init_labels)
    raise ValueError(f"unknown solver {solver!r}")


def _side_counts(graph, labels):
    ku = np.unique(labels[:graph.n_users]).size
    kv = np.unique(labels[graph.n_users:]).size
    return ku, kv


def fit_gamma(graph: BipartiteGraph, wu, wv, budget: int, *,
              max_iters: int = 8, solver: str = "jax",
              grid: int = 10, gamma0: float = 1.0,
              warm_start: bool = True,
              ) -> Tuple[float, np.ndarray, int]:
    """Pick gamma on a log-grid: best bipartite modularity s.t. K <= budget.

    K(gamma) is NOT monotone for the side-synchronous solver (measured on
    synthetic Gowalla: K dips between gamma=4 and 16 while quality rises),
    so a budget bisection can lock onto a poor plateau. Bipartite
    modularity of the resulting partition tracks downstream Recall@20
    almost perfectly (see EXPERIMENTS.md §Paper-validation/gamma-proxy),
    and evaluating it costs one pass over the edges — so we grid-search
    gamma and keep the most-modular partition that fits the budget.
    Matches the paper's protocol of tuning gamma per dataset (Table 7)
    without a validation training run.

    warm_start: the grid is walked from the LARGEST gamma down, each
    solve seeded with the previous (finer) partition instead of
    singletons. Label propagation can only merge/relabel into existing
    neighbor labels — it never mints new ones — so warm starts are safe
    exactly in the fine->coarse direction: lowering gamma only asks for
    more merging. Adjacent gammas share most of their structure, so LP
    converges in fewer sweeps and never re-discovers the same coarse
    clusters from scratch. The x2-refinement probes are seeded from the
    nearest finer grid partition for the same reason
    (tests/test_warm_start.py asserts identical-or-better modularity at
    equal budget on the synthetic dataset).
    """
    from .metrics import bipartite_modularity
    gammas = [float(gamma0) * (4.0 ** i) for i in range(-3, grid - 3)]
    best = None          # (modularity, gamma, labels, iters)
    fallback = None      # (K, gamma, labels, iters) closest above budget
    prev = None          # previous (finer) grid partition, warm-start seed
    grid_labels = {}     # gamma -> labels, for seeding the refinement
    for g in sorted(gammas, reverse=True):
        labels, it = _solve(graph, wu, wv, g, budget, max_iters, solver,
                            init_labels=prev if warm_start else None)
        if warm_start:
            prev = labels
        grid_labels[g] = labels
        ku, kv = _side_counts(graph, labels)
        k = ku + kv
        if k <= budget:
            q = bipartite_modularity(graph, labels)
            if best is None or q > best[0]:
                best = (q, g, labels, it)
        elif fallback is None or k < fallback[0]:
            fallback = (k, g, labels, it)
    if best is None:
        _, g, labels, it = fallback
        return g, labels, it
    # refinement: the grid is x4-spaced; probe the x2 neighbours
    for g in (best[1] * 2.0, best[1] / 2.0):
        seed = None
        if warm_start:
            finer = [gg for gg in grid_labels if gg > g]
            seed = grid_labels[min(finer)] if finer else None
        labels, it = _solve(graph, wu, wv, g, budget, max_iters, solver,
                            init_labels=seed)
        ku, kv = _side_counts(graph, labels)
        if ku + kv <= budget:
            q = bipartite_modularity(graph, labels)
            if q > best[0]:
                best = (q, g, labels, it)
    return best[1], best[2], best[3]


def secondary_user_labels(graph: BipartiteGraph, labels: np.ndarray,
                          wu, wv, gamma: float, solver: str = "jax",
                          ) -> np.ndarray:
    """Secondary user clusters (Alg. 2 line 18).

    The paper reruns the user sweep once; at a converged fixed point that
    reproduces the primary labels exactly, which would make SCU a no-op.
    Matching the stated motivation ("users share taste similarities with
    various user groups") we take the RUNNER-UP label: the best-scoring
    candidate cluster other than the primary one (falling back to the
    primary for users with a single candidate). Recorded in DESIGN.md.
    """
    if solver == "numpy":
        lab = labels.astype(np.int64).copy()
        nu = graph.n_users
        u_indptr, u_nbrs = graph.user_csr()
        n = graph.n_nodes
        w_v_by_label = np.bincount(lab[nu:], weights=wv, minlength=n)
        out = lab[:nu].copy()
        for i in range(nu):
            nbrs = u_nbrs[u_indptr[i]:u_indptr[i + 1]]
            if nbrs.size == 0:
                continue
            cand, cnt = np.unique(lab[nu + nbrs], return_counts=True)
            own = lab[i]
            keep = cand != own
            if not keep.any():
                continue
            scores = (cnt - gamma * wu[i] * w_v_by_label[cand])[keep]
            out[i] = cand[keep][int(np.argmax(scores))]
        return out.astype(np.int32)
    import jax
    import jax.numpy as jnp
    nu, n = graph.n_users, graph.n_nodes
    lab = jnp.asarray(labels, jnp.int32)
    own = lab[:nu]
    item_labels = lab[nu:]
    wv_by_label = jax.ops.segment_sum(jnp.asarray(wv, jnp.float32),
                                      item_labels, num_segments=n)
    eu = jnp.asarray(graph.edge_u)
    cand_lab = item_labels[jnp.asarray(graph.edge_v)]
    # group (user, label) pairs as in the solver, then argmax w/o primary
    o1 = jnp.argsort(cand_lab, stable=True)
    o2 = jnp.argsort(eu[o1], stable=True)
    order = o1[o2]
    node_s, lab_s = eu[order], cand_lab[order]
    e = node_s.shape[0]
    new_grp = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (node_s[1:] != node_s[:-1]) | (lab_s[1:] != lab_s[:-1])])
    gid = jnp.cumsum(new_grp.astype(jnp.int32)) - 1
    cnt = jax.ops.segment_sum(jnp.ones((e,), jnp.float32), gid,
                              num_segments=e, indices_are_sorted=True)[gid]
    wu_j = jnp.asarray(wu, jnp.float32)
    score = cnt - jnp.float32(gamma) * wu_j[node_s] * wv_by_label[lab_s]
    score = jnp.where(lab_s == own[node_s], -3e38, score)   # exclude primary
    best = jax.ops.segment_max(score, node_s, num_segments=nu,
                               indices_are_sorted=True)
    best = jnp.where(jnp.isfinite(best), best, -3e38)
    is_best = (score >= best[node_s]) & (score > -3e38)
    cand = jnp.where(is_best, lab_s, jnp.int32(n))
    best_lab = jax.ops.segment_min(cand, node_s, num_segments=nu,
                                   indices_are_sorted=True)
    has = best_lab < n
    return np.asarray(jnp.where(has, best_lab, own).astype(jnp.int32))


def baco_build(graph: BipartiteGraph, *, d: int = 64,
               budget: Optional[int] = None, ratio: float = 0.25,
               gamma: Optional[float] = None, scheme: str = "hws",
               solver: str = "jax", max_iters: int = 8, scu: bool = True,
               ) -> Sketch:
    """Build the BACO sketch (the paper's complete pipeline).

    budget: total codebook rows K_u + K_v. Defaults to ratio*(|U|+|V|).
    """
    if budget is None:
        budget = max(2, int(round(ratio * graph.n_nodes)))
    eff_budget = budget
    if scu:  # Alg. 2: B' = (B*d - |U|) / d
        eff_budget = max(2, int((budget * d - graph.n_users) // d))
    wu, wv = make_weights(graph, scheme)
    if gamma is None:
        gamma, labels, iters = fit_gamma(graph, wu, wv, eff_budget,
                                         max_iters=max_iters, solver=solver)
    else:
        labels, iters = _solve(graph, wu, wv, gamma, eff_budget, max_iters,
                               solver)
    pu = labels[:graph.n_users]
    pv = labels[graph.n_users:]
    meta = {"gamma": float(gamma), "iters": int(iters), "scheme": scheme,
            "solver": solver, "budget": int(budget),
            "eff_budget": int(eff_budget), "scu": bool(scu),
            "joint_labels": np.asarray(labels, dtype=np.int32)}
    if scu:
        su = secondary_user_labels(graph, labels, wu, wv, gamma, solver)
        ku, pu_c, su_c = compact_labels(pu, su)
        kv, pv_c = compact_labels(pv)
        return Sketch(np.stack([pu_c, su_c], axis=1), pv_c[:, None],
                      ku, kv, method="baco", meta=meta)
    ku, pu_c = compact_labels(pu)
    kv, pv_c = compact_labels(pv)
    return Sketch(pu_c[:, None], pv_c[:, None], ku, kv,
                  method="baco(w/o scu)", meta=meta)
