"""BACO top level — thin wrappers over the ClusterEngine.

The budget handling, gamma auto-tuning, SCU and sketch assembly that
used to live here moved into ``repro.core.engine.ClusterEngine`` (the
solver-registry dispatch layer); these functions keep the historical
API for core-internal callers and tests. New call sites should
construct a ClusterEngine directly — launch/, benchmarks/ and examples/
already do, and the arch test forbids them from importing solver
modules.

  * gamma given     -> run the solver, report whatever K comes out;
  * gamma=None      -> log-grid search keeping the partition with the
                       best bipartite modularity among those fitting the
                       budget (see ClusterEngine.fit_gamma for why a
                       budget bisection is unsafe).

SCU (Alg. 2): with secondary user sketches the budget is tightened to
B' = (B*d - |U|)/d, then ONE extra user half-step over the converged
labels yields the secondary assignment; primary+secondary user labels
are compacted jointly so both index one user codebook.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .engine import ClusterEngine
from .graph import BipartiteGraph
from .sketch import Sketch

__all__ = ["baco_build", "fit_gamma", "secondary_user_labels"]


def fit_gamma(graph: BipartiteGraph, wu, wv, budget: int, *,
              max_iters: int = 8, solver: str = "jax",
              grid: int = 10, gamma0: float = 1.0,
              warm_start: bool = True, batched: bool = False,
              ) -> Tuple[float, np.ndarray, int]:
    """ClusterEngine.fit_gamma with the historical signature."""
    return ClusterEngine(solver=solver).fit_gamma(
        graph, wu, wv, budget, max_iters=max_iters, grid=grid,
        gamma0=gamma0, warm_start=warm_start, batched=batched)


def secondary_user_labels(graph: BipartiteGraph, labels: np.ndarray,
                          wu, wv, gamma: float, solver: str = "jax",
                          ) -> np.ndarray:
    """ClusterEngine.secondary_user_labels with the historical signature."""
    return ClusterEngine(solver=solver).secondary_user_labels(
        graph, labels, wu, wv, gamma)


def baco_build(graph: BipartiteGraph, *, d: int = 64,
               budget: Optional[int] = None, ratio: float = 0.25,
               gamma: Optional[float] = None, scheme: str = "hws",
               solver: str = "jax", max_iters: int = 8, scu: bool = True,
               batched_gamma: bool = False) -> Sketch:
    """ClusterEngine.build with the historical signature."""
    return ClusterEngine(solver=solver).build(
        graph, d=d, budget=budget, ratio=ratio, gamma=gamma, scheme=scheme,
        max_iters=max_iters, scu=scu, batched_gamma=batched_gamma)
