"""Node weighting schemes for the unified balanced co-clustering framework.

Table 2 of the paper: every classic method is (gamma, w_u, w_v, solver).
The weights parameterize the volume-balance penalty
    p(k) = (#edges into cluster k) - gamma * w_self * W_other_side(k).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import BipartiteGraph

__all__ = ["make_weights", "WEIGHT_SCHEMES"]

WEIGHT_SCHEMES = (
    "hws",          # BACO: w_u = d(u)/sqrt|E|,  w_v = 1/sqrt|V|
    "modularity",   # Louvain/Leiden/LPAb: w = d(x)/sqrt|E| on both sides
    "cpm",          # constant 1 on both sides
    "reverse_hws",  # ablation: w_u = 1/sqrt|U|, w_v = d(v)/sqrt|E|
    "uniform_norm", # 1/sqrt|U| and 1/sqrt|V| (scale-free CPM)
)


def make_weights(graph: BipartiteGraph, scheme: str) -> Tuple[np.ndarray, np.ndarray]:
    """Return (w_users float64[|U|], w_items float64[|V|])."""
    e = max(graph.n_edges, 1)
    du = graph.user_degrees().astype(np.float64)
    dv = graph.item_degrees().astype(np.float64)
    if scheme == "hws":
        return du / np.sqrt(e), np.full(graph.n_items, 1.0 / np.sqrt(max(graph.n_items, 1)))
    if scheme == "modularity":
        return du / np.sqrt(e), dv / np.sqrt(e)
    if scheme == "cpm":
        return np.ones(graph.n_users), np.ones(graph.n_items)
    if scheme == "reverse_hws":
        return (np.full(graph.n_users, 1.0 / np.sqrt(max(graph.n_users, 1))),
                dv / np.sqrt(e))
    if scheme == "uniform_norm":
        return (np.full(graph.n_users, 1.0 / np.sqrt(max(graph.n_users, 1))),
                np.full(graph.n_items, 1.0 / np.sqrt(max(graph.n_items, 1))))
    raise ValueError(f"unknown weighting scheme {scheme!r}; options: {WEIGHT_SCHEMES}")
