"""BACO core: balanced co-clustering for embedding table compression."""
from .graph import BipartiteGraph, node_aligned_bounds, pad_rung
from .sketch import Sketch, compact_labels
from .weights import make_weights, WEIGHT_SCHEMES
from .engine import (ClusterEngine, ClusterSolver, available_solvers,
                     get_solver, normalize_solver, register_solver)
from .baco import baco_build, fit_gamma, secondary_user_labels
from .baselines import build_sketch, BASELINES
from . import candidates, metrics, solver_jax, solver_numpy

__all__ = [
    "BipartiteGraph", "node_aligned_bounds", "pad_rung", "Sketch",
    "compact_labels", "make_weights",
    "WEIGHT_SCHEMES", "ClusterEngine", "ClusterSolver", "available_solvers",
    "get_solver", "normalize_solver", "register_solver",
    "baco_build", "fit_gamma", "secondary_user_labels",
    "build_sketch", "BASELINES", "candidates", "metrics", "solver_jax",
    "solver_numpy",
]
