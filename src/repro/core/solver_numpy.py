"""Paper-faithful sequential solver — Algorithm 1 verbatim (CPU / numpy).

Asynchronous greedy sweep: nodes are visited in order, each immediately
adopts the best label among its neighbors' labels and its own, and the
global cluster weight sums are updated incrementally in O(1) per move
(§4.6). This is the reference implementation the TPU-native solver in
``solver_jax`` is validated against (same objective, not same labels —
greedy visit order differs by design).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import BipartiteGraph

__all__ = ["lp_solve_sequential"]


def lp_solve_sequential(graph: BipartiteGraph, w_users: np.ndarray,
                        w_items: np.ndarray, gamma: float,
                        budget: int | None = None, max_iters: int = 8,
                        init_labels: np.ndarray | None = None,
                        ) -> Tuple[np.ndarray, int]:
    """Algorithm 1. Returns (labels int32[n_nodes] shared id space, iters).

    init_labels warm-starts the sweep from a previous partition (e.g. the
    neighbouring gamma grid point in fit_gamma) instead of singletons.
    """
    nu, nv = graph.n_users, graph.n_items
    n = nu + nv
    u_indptr, u_nbrs = graph.user_csr()     # user -> item neighbors
    v_indptr, v_nbrs = graph.item_csr()     # item -> user neighbors
    if init_labels is None:
        labels = np.arange(n, dtype=np.int64)
    else:
        labels = np.asarray(init_labels, np.int64).copy()
    # global per-label weight sums, updated incrementally on every move
    w_u_by_label = np.zeros(n, dtype=np.float64)
    np.add.at(w_u_by_label, labels[:nu], w_users)
    w_v_by_label = np.zeros(n, dtype=np.float64)
    np.add.at(w_v_by_label, labels[nu:], w_items)

    gamma = float(gamma)
    it = 0
    for it in range(1, max_iters + 1):
        moved = 0
        # ---- users (Eq. 13) ------------------------------------------------
        for i in range(nu):
            nbrs = u_nbrs[u_indptr[i]:u_indptr[i + 1]]
            if nbrs.size == 0:
                continue
            nbr_labels = labels[nu + nbrs]
            cand, cnt = np.unique(nbr_labels, return_counts=True)
            own = labels[i]
            scores = cnt - gamma * w_users[i] * w_v_by_label[cand]
            own_score = (cnt[cand == own].sum()
                         - gamma * w_users[i] * w_v_by_label[own])
            j = int(np.argmax(scores))
            if scores[j] > own_score:
                w_u_by_label[own] -= w_users[i]
                labels[i] = cand[j]
                w_u_by_label[cand[j]] += w_users[i]
                moved += 1
        # ---- items (Eq. 14) ------------------------------------------------
        for j in range(nv):
            nbrs = v_nbrs[v_indptr[j]:v_indptr[j + 1]]
            if nbrs.size == 0:
                continue
            nbr_labels = labels[nbrs]
            cand, cnt = np.unique(nbr_labels, return_counts=True)
            own = labels[nu + j]
            scores = cnt - gamma * w_items[j] * w_u_by_label[cand]
            own_score = (cnt[cand == own].sum()
                         - gamma * w_items[j] * w_u_by_label[own])
            i2 = int(np.argmax(scores))
            if scores[i2] > own_score:
                w_v_by_label[own] -= w_items[j]
                labels[nu + j] = cand[i2]
                w_v_by_label[cand[i2]] += w_items[j]
                moved += 1
        if moved == 0:
            break
        # budget check AFTER the sweep (matches solver_jax): a warm-start
        # seed already within budget must still feel this gamma at least
        # once, else the whole grid collapses onto the seed partition
        if budget is not None:
            ku = np.unique(labels[:nu]).size
            kv = np.unique(labels[nu:]).size
            if ku + kv <= budget:
                break
    return labels.astype(np.int32), it
