"""Edge-partitioned multi-device co-clustering solver ("jax_sharded").

Same math as ``solver_jax`` — the single-device half-step is imported,
not reimplemented — distributed with the edge-partition strategy from
``repro.distributed.sharding``: each device owns a contiguous range of
the updating side's nodes plus exactly the edges into that range
(padded blocks, precomputed host-side and cached on the graph), runs
the gather/segment half-step locally, and combines only the per-label
opposite-side weight totals (one f32[n_nodes] vector) with a psum.
Labels stay replicated — they are int32[n_nodes], small even for
million-node graphs — so the convergence and budget checks of the
device-resident while_loop are unchanged.

On a mesh of 1 this reduces to the single-device solver bit-for-bit;
on N devices each sweep's per-device work drops to E/N edge-block
sorting, which is the O(E log E) term that dominates million-edge
solves. Parity caveat: the psum reassociates the f32 per-label weight
sums, so a candidate score that ties the single-device value to the
last ulp could in principle resolve differently on N > 1 — the edge
counts (exact integers) and the argmax tie-break are unaffected, and
tests pin label-for-label equality on CPU meshes of 1 and 4 on the
synthetic dataset.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import (cluster_mesh, edge_partition,
                                        edge_partitioned_half_step,
                                        pad_to_shards)

from .graph import BipartiteGraph
from .solver_jax import _half_step, solve_loop

__all__ = ["lp_solve_sharded"]


def _pad_dev(x, m: int):
    """Trace-safe zero-pad of a 1-D device array to length m."""
    if x.shape[0] == m:
        return x
    return jnp.zeros(m, x.dtype).at[:x.shape[0]].set(x)


@functools.partial(
    jax.jit, static_argnames=("mesh", "n_users", "n_items", "nps_u", "nps_v"))
def _solve_sharded_jit(labels, u_node, u_opp, v_node, v_opp, wu_pad, wv_pad,
                       gamma, budget, max_iters, *, mesh, n_users: int,
                       n_items: int, nps_u: int, nps_v: int):
    n = n_users + n_items
    s = mesh.devices.size
    user_half = edge_partitioned_half_step(mesh, _half_step, n, nps_u)
    item_half = edge_partitioned_half_step(mesh, _half_step, n, nps_v)

    def step(labels):
        item_lab = labels[n_users:]
        lab_v_pad = _pad_dev(item_lab, s * nps_v)
        new_u = user_half(u_node, u_opp, _pad_dev(labels[:n_users],
                                                  s * nps_u),
                          wu_pad, lab_v_pad, wv_pad, item_lab,
                          gamma)[:n_users]
        new_v = item_half(v_node, v_opp, lab_v_pad, wv_pad,
                          _pad_dev(new_u, s * nps_u), wu_pad, new_u,
                          gamma)[:n_items]
        return jnp.concatenate([new_u, new_v])

    return solve_loop(step, labels, budget, max_iters, n_users=n_users,
                      n_items=n_items)


def _partitions(graph: BipartiteGraph, n_shards: int):
    """Per-shard edge blocks + padded weights, memoized on the graph."""
    def build():
        u_node, u_opp, nps_u = edge_partition(graph.edge_u, graph.edge_v,
                                              graph.n_users, n_shards)
        ev_byv = graph.edge_v[graph.perm_by_item]
        eu_byv = graph.edge_u[graph.perm_by_item]
        v_node, v_opp, nps_v = edge_partition(ev_byv, eu_byv,
                                              graph.n_items, n_shards)
        return u_node, u_opp, nps_u, v_node, v_opp, nps_v
    return graph._memo(("edge_partition", n_shards), build)


def lp_solve_sharded(graph: BipartiteGraph, w_users, w_items, gamma: float,
                     budget: int | None = None, max_iters: int = 8,
                     init_labels: np.ndarray | None = None, *,
                     mesh=None) -> Tuple[np.ndarray, int]:
    """Multi-device lp_solve: same signature/semantics as
    solver_jax.lp_solve plus an optional 1-D mesh (defaults to every
    local device on an "edge" axis)."""
    if mesh is None:
        mesh = cluster_mesh()
    s = int(mesh.devices.size)
    u_node, u_opp, nps_u, v_node, v_opp, nps_v = _partitions(graph, s)
    wu_pad = pad_to_shards(np.asarray(w_users, np.float32), s, nps_u)
    wv_pad = pad_to_shards(np.asarray(w_items, np.float32), s, nps_v)
    if init_labels is None:
        labels = jnp.arange(graph.n_nodes, dtype=jnp.int32)
    else:
        labels = jnp.asarray(init_labels, jnp.int32)
    labels, it = _solve_sharded_jit(
        labels, u_node, u_opp, v_node, v_opp, wu_pad, wv_pad,
        jnp.float32(gamma), jnp.int32(0 if budget is None else budget),
        jnp.int32(max_iters), mesh=mesh, n_users=graph.n_users,
        n_items=graph.n_items, nps_u=nps_u, nps_v=nps_v)
    return np.asarray(labels), int(it)
