"""Louvain for the unified balanced co-clustering objective (Eq. 9).

This is the GraphHash [56] baseline family: greedy local moves + graph
aggregation, optimizing  Σ_ij (B_ij − γ·w_i·w_j)·δ(i,j)  with the chosen
weighting scheme (modularity weights → classic bipartite Louvain; cpm
weights → CPM-Louvain). Pure numpy, host-side preprocessing.

Known limitation reproduced on purpose: the aggregation phase merges small
clusters, exhibiting the resolution limit the paper targets (§4.4 Remark).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import BipartiteGraph

__all__ = ["louvain_solve"]


def _local_moves(nu, nv, u_indptr, u_nbrs, u_w_edges, v_indptr, v_nbrs,
                 v_w_edges, wu, wv, gamma, labels, max_sweeps=8):
    """Greedy sweeps over a (possibly aggregated) bipartite multigraph."""
    n = nu + nv
    w_u_by_label = np.bincount(labels[:nu], weights=wu, minlength=n)
    w_v_by_label = np.bincount(labels[nu:], weights=wv, minlength=n)
    for _ in range(max_sweeps):
        moved = 0
        for i in range(nu):
            sl = slice(u_indptr[i], u_indptr[i + 1])
            nbrs, wts = u_nbrs[sl], u_w_edges[sl]
            if nbrs.size == 0:
                continue
            nbr_labels = labels[nu + nbrs]
            cand, inv = np.unique(nbr_labels, return_inverse=True)
            cnt = np.bincount(inv, weights=wts)
            own = labels[i]
            scores = cnt - gamma * wu[i] * w_v_by_label[cand]
            own_score = (cnt[cand == own].sum()
                         - gamma * wu[i] * w_v_by_label[own])
            j = int(np.argmax(scores))
            if scores[j] > own_score + 1e-12:
                labels[i] = cand[j]
                moved += 1
        for j in range(nv):
            sl = slice(v_indptr[j], v_indptr[j + 1])
            nbrs, wts = v_nbrs[sl], v_w_edges[sl]
            if nbrs.size == 0:
                continue
            nbr_labels = labels[nbrs]
            cand, inv = np.unique(nbr_labels, return_inverse=True)
            cnt = np.bincount(inv, weights=wts)
            own = labels[nu + j]
            scores = cnt - gamma * wv[j] * w_u_by_label[cand]
            own_score = (cnt[cand == own].sum()
                         - gamma * wv[j] * w_u_by_label[own])
            i2 = int(np.argmax(scores))
            if scores[i2] > own_score + 1e-12:
                labels[nu + j] = cand[i2]
                moved += 1
        w_u_by_label = np.bincount(labels[:nu], weights=wu, minlength=n)
        w_v_by_label = np.bincount(labels[nu:], weights=wv, minlength=n)
        if moved == 0:
            break
    return labels


def louvain_solve(graph: BipartiteGraph, wu: np.ndarray, wv: np.ndarray,
                  gamma: float, max_levels: int = 5,
                  ) -> Tuple[np.ndarray, int]:
    """Returns (labels int32[n_nodes] shared id space, levels run)."""
    nu, nv = graph.n_users, graph.n_items
    # level-0 multigraph = the input graph with unit edge weights
    eu = graph.edge_u.astype(np.int64)
    ev = graph.edge_v.astype(np.int64)
    ew = np.ones(eu.shape[0], dtype=np.float64)
    cur_wu, cur_wv = wu.astype(np.float64), wv.astype(np.float64)
    # mapping from original nodes to current super-nodes (per side)
    map_u = np.arange(nu, dtype=np.int64)
    map_v = np.arange(nv, dtype=np.int64)
    levels = 0
    for levels in range(1, max_levels + 1):
        cnu, cnv = cur_wu.shape[0], cur_wv.shape[0]
        # CSR both ways for the multigraph
        o_u = np.argsort(eu, kind="stable")
        o_v = np.argsort(ev, kind="stable")
        u_indptr = np.zeros(cnu + 1, np.int64)
        np.cumsum(np.bincount(eu, minlength=cnu), out=u_indptr[1:])
        v_indptr = np.zeros(cnv + 1, np.int64)
        np.cumsum(np.bincount(ev, minlength=cnv), out=v_indptr[1:])
        labels = np.arange(cnu + cnv, dtype=np.int64)
        labels = _local_moves(cnu, cnv, u_indptr, ev[o_u], ew[o_u],
                              v_indptr, eu[o_v], ew[o_v],
                              cur_wu, cur_wv, gamma, labels)
        lu, lv = labels[:cnu], labels[cnu:]
        uniq_u, new_u = np.unique(lu, return_inverse=True)
        uniq_v, new_v = np.unique(lv, return_inverse=True)
        if uniq_u.size == cnu and uniq_v.size == cnv:
            break  # no merges -> converged
        # aggregate: same-label user(item) super-nodes merge; BUT user and
        # item super-nodes sharing a label stay linked only through edges.
        map_u = new_u[map_u]
        map_v = new_v[map_v]
        # merge parallel edges
        key = new_u[eu] * np.int64(uniq_v.size) + new_v[ev]
        skey, inv = np.unique(key, return_inverse=True)
        ew = np.bincount(inv, weights=ew)
        eu = skey // uniq_v.size
        ev = skey % uniq_v.size
        cur_wu = np.bincount(new_u, weights=cur_wu)
        cur_wv = np.bincount(new_v, weights=cur_wv)
        # keep cross-side co-membership: encode shared labels by re-running
        # moves at the next level (labels reset to singletons of supernodes).
    # produce final labels in the ORIGINAL shared id space; user cluster c
    # and item cluster c' co-labelled iff they were merged into the same
    # label at the last level with cross-side alignment pass below.
    nu2, nv2 = cur_wu.shape[0], cur_wv.shape[0]
    # final alignment: one LP-style pass assigning each item supernode to
    # the user-side label it connects to most (ties the two sides' ids).
    final = np.concatenate([np.arange(nu2, dtype=np.int64),
                            np.arange(nv2, dtype=np.int64) + nu2])
    o_u = np.argsort(eu, kind="stable")
    o_v = np.argsort(ev, kind="stable")
    u_indptr = np.zeros(nu2 + 1, np.int64)
    np.cumsum(np.bincount(eu, minlength=nu2), out=u_indptr[1:])
    v_indptr = np.zeros(nv2 + 1, np.int64)
    np.cumsum(np.bincount(ev, minlength=nv2), out=v_indptr[1:])
    final = _local_moves(nu2, nv2, u_indptr, ev[o_u], ew[o_u],
                         v_indptr, eu[o_v], ew[o_v],
                         cur_wu, cur_wv, gamma, final, max_sweeps=2)
    out = np.empty(nu + nv, dtype=np.int32)
    out[:nu] = final[map_u]
    out[nu:] = final[nu2 + map_v]
    return out, levels
