"""ETC baseline zoo (paper §5.1).

Implemented here (16 of the paper's 18 + CCE in training/cce.py):
  random, frequency, double, hybrid (hashing family)
  lsh (SimHash over interaction rows)
  lp (gamma=0 label propagation), lpab (modularity-weight LP),
  louvain_modularity (GraphHash), louvain_cpm, double_graphhash,
  leiden (Louvain + balanced-LP refinement; labeled an approximation),
  scc (Dhillon'01 spectral co-clustering), sbc (Kluger'03 per-side
  spectral), itcc (information-theoretic co-clustering),
  baco variants (via core.baco)

CCE ("clustering the sketch", learned) lives in training/cce.py since it
couples to the training loop. Out of scope, documented in DESIGN.md:
LEGCF/DHE (learned, require per-epoch model surgery) and
infomap/BiMLPA/BRIM/EBMD — external adaptive-K community detectors the
paper runs via third-party packages.

Every builder returns a `Sketch` so downstream training is uniform.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .graph import BipartiteGraph
from .sketch import Sketch, compact_labels
from .weights import make_weights
from . import solver_jax
from .louvain import louvain_solve

__all__ = ["build_sketch", "BASELINES"]


def _split_budget(graph: BipartiteGraph, budget: int):
    """Split total codebook budget across sides proportionally to counts."""
    nu, nv = graph.n_users, graph.n_items
    ku = max(1, int(round(budget * nu / (nu + nv))))
    kv = max(1, budget - ku)
    ku = min(ku, nu)
    kv = min(kv, nv)
    return ku, kv


def _hash(ids: np.ndarray, k: int, seed: int) -> np.ndarray:
    """Deterministic splittable integer hash -> [0, k)."""
    x = ids.astype(np.uint64) + np.uint64((seed * 0x9E3779B97F4A7C15) % (1 << 64))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return (x % np.uint64(k)).astype(np.int32)


# --------------------------------------------------------------------------
# hashing family
# --------------------------------------------------------------------------
def random_sketch(graph, budget, seed=0):
    ku, kv = _split_budget(graph, budget)
    return Sketch(_hash(np.arange(graph.n_users), ku, seed)[:, None],
                  _hash(np.arange(graph.n_items), kv, seed + 1)[:, None],
                  ku, kv, method="random")


def frequency_sketch(graph, budget, seed=0):
    """Half the bins are private to the most frequent entities [16, 66]."""
    ku, kv = _split_budget(graph, budget)

    def per_side(deg, k, s):
        n = deg.shape[0]
        own = k // 2
        order = np.argsort(-deg, kind="stable")
        idx = np.empty(n, dtype=np.int32)
        top = order[:own]
        idx[top] = np.arange(own, dtype=np.int32)
        rest = order[own:]
        idx[rest] = own + _hash(rest, max(k - own, 1), s)
        return idx

    return Sketch(per_side(graph.user_degrees(), ku, seed)[:, None],
                  per_side(graph.item_degrees(), kv, seed + 1)[:, None],
                  ku, kv, method="frequency")


def double_sketch(graph, budget, seed=0):
    """Two independent hashes; embeddings summed (2-hot sketch) [66]."""
    ku, kv = _split_budget(graph, budget)
    u = np.stack([_hash(np.arange(graph.n_users), ku, seed),
                  _hash(np.arange(graph.n_users), ku, seed + 7)], axis=1)
    v = np.stack([_hash(np.arange(graph.n_items), kv, seed + 1),
                  _hash(np.arange(graph.n_items), kv, seed + 8)], axis=1)
    return Sketch(u, v, ku, kv, method="double")


def hybrid_sketch(graph, budget, seed=0):
    """Frequent entities get private bins; the rest are double-hashed [66]."""
    ku, kv = _split_budget(graph, budget)

    def per_side(deg, k, s):
        n = deg.shape[0]
        own = k // 2
        order = np.argsort(-deg, kind="stable")
        idx = np.empty((n, 2), dtype=np.int32)
        top = order[:own]
        idx[top, 0] = np.arange(own, dtype=np.int32)
        idx[top, 1] = idx[top, 0]            # degenerate 2-hot = 1-hot * 2
        rest = order[own:]
        idx[rest, 0] = own + _hash(rest, max(k - own, 1), s)
        idx[rest, 1] = own + _hash(rest, max(k - own, 1), s + 7)
        return idx

    return Sketch(per_side(graph.user_degrees(), ku, seed),
                  per_side(graph.item_degrees(), kv, seed + 1),
                  ku, kv, method="hybrid")


def lsh_sketch(graph, budget, seed=0, n_bits=16):
    """SimHash over interaction rows: sign(B @ R) bucketed mod K [10, 67]."""
    ku, kv = _split_budget(graph, budget)
    rng = np.random.default_rng(seed)

    def per_side(indptr, nbrs, dim, k):
        n = indptr.shape[0] - 1
        r = rng.standard_normal((dim, n_bits)).astype(np.float32)
        sig = np.zeros((n, n_bits), dtype=np.float32)
        # sparse row @ R accumulated via add.at (no |n|x|dim| dense)
        src = np.repeat(np.arange(n), np.diff(indptr))
        np.add.at(sig, src, r[nbrs])
        bits = (sig > 0).astype(np.uint64)
        code = np.zeros(n, dtype=np.uint64)
        for b in range(n_bits):
            code |= bits[:, b] << np.uint64(b)
        return (code % np.uint64(k)).astype(np.int32)

    ui, un = graph.user_csr()
    vi, vn = graph.item_csr()
    return Sketch(per_side(ui, un, graph.n_items, ku)[:, None],
                  per_side(vi, vn, graph.n_users, kv)[:, None],
                  ku, kv, method="lsh")


# --------------------------------------------------------------------------
# graph clustering family
# --------------------------------------------------------------------------
def _lp_family(graph, budget, scheme, gamma, max_iters=8):
    wu, wv = make_weights(graph, scheme)
    labels, it = solver_jax.lp_solve(graph, wu, wv, gamma, budget, max_iters)
    ku, ul = compact_labels(labels[:graph.n_users])
    kv, il = compact_labels(labels[graph.n_users:])
    return Sketch(ul[:, None], il[:, None], ku, kv,
                  method=f"lp[{scheme},g={gamma}]",
                  meta={"iters": it, "gamma": gamma,
                        "joint_labels": labels.astype(np.int32)})


def lp_sketch(graph, budget, seed=0, max_iters=8):
    """Plain LP [38]: gamma = 0, no balance control."""
    return _lp_family(graph, budget, "cpm", 0.0, max_iters=max_iters)


def lpab_sketch(graph, budget, seed=0, gamma=1.0, max_iters=8):
    """LPAb [3]: LP solver with modularity weights."""
    return _lp_family(graph, budget, "modularity", gamma,
                      max_iters=max_iters)


def _louvain_family(graph, budget, scheme, gamma):
    wu, wv = make_weights(graph, scheme)
    labels, lv = louvain_solve(graph, wu, wv, gamma)
    ku, ul = compact_labels(labels[:graph.n_users])
    kv, il = compact_labels(labels[graph.n_users:])
    return Sketch(ul[:, None], il[:, None], ku, kv,
                  method=f"louvain[{scheme},g={gamma}]",
                  meta={"levels": lv, "gamma": gamma,
                        "joint_labels": labels.astype(np.int32)})


def louvain_modularity_sketch(graph, budget, seed=0, gamma=1.0):
    """GraphHash [56]: bipartite-modularity Louvain."""
    return _louvain_family(graph, budget, "modularity", gamma)


def louvain_cpm_sketch(graph, budget, seed=0, gamma=None):
    if gamma is None:  # CPM gamma must sit at edge-density scale
        gamma = max(graph.n_edges / (graph.n_users * graph.n_items), 1e-9) * 4
    return _louvain_family(graph, budget, "cpm", gamma)


# --------------------------------------------------------------------------
# co-clustering family (spectral)
# --------------------------------------------------------------------------
def _kmeans(x, k, seed=0, iters=25):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    k = min(k, n)
    centers = x[rng.choice(n, size=k, replace=False)]
    assign = np.zeros(n, dtype=np.int32)
    for _ in range(iters):
        d = ((x[:, None, :] - centers[None, :, :]) ** 2).sum(-1) \
            if n * k * x.shape[1] < 5e7 else None
        if d is None:  # chunked distance for big inputs
            d = np.empty((n, k), dtype=np.float32)
            x2 = (x * x).sum(-1, keepdims=True)
            c2 = (centers * centers).sum(-1)
            step = max(1, int(5e7 // max(k, 1)))
            for s in range(0, n, step):
                e = min(n, s + step)
                d[s:e] = x2[s:e] + c2[None, :] - 2.0 * x[s:e] @ centers.T
        new = d.argmin(1).astype(np.int32)
        if np.array_equal(new, assign):
            break
        assign = new
        for c in range(k):
            m = assign == c
            if m.any():
                centers[c] = x[m].mean(0)
    return assign


def scc_sketch(graph, budget, seed=0, n_vecs=None):
    """Spectral co-clustering [12]: SVD of D_u^-1/2 B D_v^-1/2 + k-means."""
    import scipy.sparse as sp
    import scipy.sparse.linalg as spla
    ku, kv = _split_budget(graph, budget)
    k = min(ku, kv)
    ell = n_vecs or max(2, min(int(np.ceil(np.log2(max(k, 2)))) + 1, 32))
    du = np.maximum(graph.user_degrees(), 1).astype(np.float64)
    dv = np.maximum(graph.item_degrees(), 1).astype(np.float64)
    b = sp.coo_matrix((np.ones(graph.n_edges),
                       (graph.edge_u, graph.edge_v)),
                      shape=(graph.n_users, graph.n_items)).tocsr()
    bn = sp.diags(du ** -0.5) @ b @ sp.diags(dv ** -0.5)
    u, s, vt = spla.svds(bn, k=min(ell + 1, min(bn.shape) - 1))
    order = np.argsort(-s)[1:ell + 1]          # drop trivial top vector
    zu = (du[:, None] ** -0.5) * u[:, order]
    zv = (dv[:, None] ** -0.5) * vt[order].T
    z = np.concatenate([zu, zv], axis=0).astype(np.float32)
    joint = _kmeans(z, k, seed=seed)
    ku2, ul = compact_labels(joint[:graph.n_users])
    kv2, il = compact_labels(joint[graph.n_users:])
    return Sketch(ul[:, None], il[:, None], ku2, kv2, method="scc",
                  meta={"joint_labels": joint.astype(np.int32)})


def sbc_sketch(graph, budget, seed=0):
    """Spectral biclustering [29]: per-side k-means on singular vectors."""
    import scipy.sparse as sp
    import scipy.sparse.linalg as spla
    ku, kv = _split_budget(graph, budget)
    ell = max(2, min(int(np.ceil(np.log2(max(min(ku, kv), 2)))) + 1, 32))
    du = np.maximum(graph.user_degrees(), 1).astype(np.float64)
    dv = np.maximum(graph.item_degrees(), 1).astype(np.float64)
    b = sp.coo_matrix((np.ones(graph.n_edges),
                       (graph.edge_u, graph.edge_v)),
                      shape=(graph.n_users, graph.n_items)).tocsr()
    bn = sp.diags(du ** -0.5) @ b @ sp.diags(dv ** -0.5)
    u, s, vt = spla.svds(bn, k=min(ell + 1, min(bn.shape) - 1))
    order = np.argsort(-s)[1:ell + 1]
    ul = _kmeans(u[:, order].astype(np.float32), ku, seed=seed)
    il = _kmeans(vt[order].T.astype(np.float32), kv, seed=seed + 1)
    ku2, ul = compact_labels(ul)
    kv2, il = compact_labels(il)
    return Sketch(ul[:, None], il[:, None], ku2, kv2, method="sbc")


def leiden_like_sketch(graph, budget, seed=0, gamma=1.0):
    """Leiden [48], approximated: Louvain levels + a refinement pass.

    Leiden's contribution over Louvain is a refinement phase that splits
    badly-connected communities before aggregation. We approximate it by
    re-running the balanced LP solver INITIALIZED from the Louvain
    partition: the volume penalty breaks resolution-limit merges while
    well-connected communities survive. Labeled an approximation in the
    benchmark tables.
    """
    wu, wv = make_weights(graph, "modularity")
    labels, _ = louvain_solve(graph, wu, wv, gamma)
    refined, it = solver_jax.lp_solve(graph, wu, wv, gamma, budget,
                                      max_iters=3,
                                      init_labels=labels.astype(np.int32))
    ku, ul = compact_labels(refined[:graph.n_users])
    kv, il = compact_labels(refined[graph.n_users:])
    return Sketch(ul[:, None], il[:, None], ku, kv,
                  method="leiden(approx)",
                  meta={"gamma": gamma,
                        "joint_labels": refined.astype(np.int32)})


def itcc_sketch(graph, budget, seed=0, n_iters=12):
    """Information-theoretic co-clustering [13]: alternate row/column
    cluster updates minimizing the KL between p(u,v) and its co-cluster
    approximation. Dense p-matrix -> paper-scale graphs only."""
    ku, kv = _split_budget(graph, budget)
    rng = np.random.default_rng(seed)
    nu, nv = graph.n_users, graph.n_items
    p = graph.biadjacency().astype(np.float64)
    p /= p.sum()
    ru = rng.integers(0, ku, nu)
    rv = rng.integers(0, kv, nv)
    eps = 1e-12
    for _i in range(n_iters):
        # co-cluster joint + marginals
        pc = np.zeros((ku, kv))
        np.add.at(pc, (ru[:, None].repeat(nv, 1), rv[None, :].repeat(nu, 0)),
                  p)
        pu_c = pc.sum(1) + eps
        pv_c = pc.sum(0) + eps
        # q(v | item cluster) distributions per user row
        logq = np.log(pc + eps) - np.log(pu_c)[:, None] - np.log(pv_c)[None]
        # assign to the row cluster maximizing sum p(u,v) logq; random
        # tiebreak noise prevents the all-ties -> cluster-0 collapse at
        # the (uninformative) random init
        pv_agg = np.zeros((nu, kv))
        np.add.at(pv_agg.T, rv, p.T)
        su = pv_agg @ logq.T
        ru = np.argmax(su + 1e-9 * rng.random(su.shape), axis=1)
        pu_agg = np.zeros((nv, ku))
        np.add.at(pu_agg.T, ru, p)
        sv = pu_agg @ logq
        rv = np.argmax(sv + 1e-9 * rng.random(sv.shape), axis=1)
    ku2, ul = compact_labels(ru.astype(np.int64))
    kv2, il = compact_labels(rv.astype(np.int64))
    return Sketch(ul[:, None], il[:, None], ku2, kv2, method="itcc")


def double_graphhash_sketch(graph, budget, seed=0, gamma=1.0):
    """DoubleGraphHash [56]: two clusterings at different resolutions,
    combined as a 2-hot sketch (the graph analogue of double hashing)."""
    wu, wv = make_weights(graph, "modularity")
    l1, _ = louvain_solve(graph, wu, wv, gamma)
    l2, _ = louvain_solve(graph, wu, wv, gamma * 4.0)
    ku, u1, u2 = compact_labels(l1[:graph.n_users], l2[:graph.n_users])
    kv, v1, v2 = compact_labels(l1[graph.n_users:], l2[graph.n_users:])
    return Sketch(np.stack([u1, u2], 1), np.stack([v1, v2], 1), ku, kv,
                  method="double_graphhash",
                  meta={"joint_labels": l1.astype(np.int32)})


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
def _baco(graph, budget, seed=0, **kw):
    # seed accepted for registry uniformity (BACO is deterministic);
    # everything else must name a real baco_build parameter — its
    # explicit signature is the typo guard
    from .baco import baco_build
    return baco_build(graph, budget=budget, **kw)


BASELINES = {
    "random": random_sketch,
    "frequency": frequency_sketch,
    "double": double_sketch,
    "hybrid": hybrid_sketch,
    "lsh": lsh_sketch,
    "lp": lp_sketch,
    "lpab": lpab_sketch,
    "louvain_modularity": louvain_modularity_sketch,   # GraphHash
    "louvain_cpm": louvain_cpm_sketch,
    "scc": scc_sketch,
    "sbc": sbc_sketch,
    "itcc": itcc_sketch,
    "double_graphhash": double_graphhash_sketch,
    "leiden": leiden_like_sketch,
    "baco": _baco,
    "baco_no_scu": lambda g, b, **kw: _baco(g, b, scu=False, **kw),
}


# kwargs a registry entry pins itself (callers may not override them)
_PRESET_KWARGS = {"baco_no_scu": {"scu"}}


def _allowed_kwargs(name: str) -> set:
    """Keyword names the selected builder really accepts. The baco
    variants forward to ``baco_build``, so its signature is the truth
    for them (minus any kwarg the variant pins, e.g. baco_no_scu's
    ``scu``); everything else is read off the builder itself."""
    import inspect
    if name.startswith("baco"):
        from .baco import baco_build
        target = baco_build
    else:
        target = BASELINES[name]
    kinds = (inspect.Parameter.POSITIONAL_OR_KEYWORD,
             inspect.Parameter.KEYWORD_ONLY)
    allowed = {p.name for p in inspect.signature(target).parameters.values()
               if p.kind in kinds} - {"graph", "budget"}
    allowed -= _PRESET_KWARGS.get(name, set())
    return allowed | {"seed"}      # the registry always passes seed


def build_sketch(name: str, graph: BipartiteGraph, budget: int,
                 seed: int = 0, **kw) -> Sketch:
    """Build the named ETC sketch. Extra kwargs must name real
    parameters of the selected builder: kwargs are validated against
    the builder's explicit signature (no ``**_`` swallowing anywhere in
    the zoo), so a typo'd ``gamm=...`` raises TypeError up front
    instead of silently running defaults."""
    if name not in BASELINES:
        raise KeyError(f"unknown ETC method {name!r}: {sorted(BASELINES)}")
    allowed = _allowed_kwargs(name)
    unknown = sorted(set(kw) - allowed)
    if unknown:
        raise TypeError(f"build_sketch({name!r}): unexpected keyword "
                        f"argument(s) {unknown}; valid kwargs: "
                        f"{sorted(allowed)}")
    return BASELINES[name](graph, budget, seed=seed, **kw)
