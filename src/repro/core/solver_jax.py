"""TPU-native balanced co-clustering solver (side-synchronous label propagation).

The paper's Algorithm 1 is a sequential greedy sweep: each node adopts the
neighbor label maximizing

    p(k) = |N(x) ∩ C_k|  -  gamma * w_x * W_other(k)            (Eq. 13/14)

where W_other(k) is the total weight of the *opposite-side* members of
cluster k. Sequential scatter-updates do not map to TPU, so we adapt the
sweep to the bipartite structure (DESIGN.md §3):

  * update ALL users in parallel holding item labels fixed, then all items
    holding user labels fixed. Each half-step is exact w.r.t. the other
    side's labels, and the alternation kills the 2-coloring oscillation of
    fully-synchronous LP.
  * p(k) decomposes into a pure gather/segment pass:
      - per-(node, candidate-label) edge counts via one sort + searchsorted,
      - cluster weight sums W(k) via segment_sum,
      - per-node argmax via segment_max + tie-break-to-smallest-label.

Everything is fixed-shape (labels live in the shared id space [0, n_nodes))
so the whole step jits once per graph size.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import BipartiteGraph

__all__ = ["lp_solve", "lp_step", "count_side_labels"]

# plain float, not a device array: importing this module must never
# initialize the jax backend (dryrun sets XLA_FLAGS first)
_NEG = -3e38


def _half_step(node_of_edge, cand_lab_of_edge, w_self, w_other_by_label,
               own_labels, gamma, n_side, n_labels):
    """One parallel half-step for one side of the bipartite graph.

    node_of_edge: int32[E] updating-side endpoint, SORTED ascending.
    cand_lab_of_edge: int32[E] current label of the opposite endpoint.
    w_self: f32[n_side] weights of updating-side nodes.
    w_other_by_label: f32[n_labels] summed opposite-side weight per label.
    own_labels: int32[n_side] current labels of updating side.
    Returns new labels int32[n_side].
    """
    e = node_of_edge.shape[0]
    # --- group edges by (node, candidate label): counts per group ---------
    # int32-safe lexicographic sort: stable argsort by label, then by node.
    o1 = jnp.argsort(cand_lab_of_edge, stable=True)
    o2 = jnp.argsort(node_of_edge[o1], stable=True)
    order = o1[o2]
    node_s = node_of_edge[order]
    lab_s = cand_lab_of_edge[order]
    new_grp = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (node_s[1:] != node_s[:-1]) | (lab_s[1:] != lab_s[:-1])])
    gid = jnp.cumsum(new_grp.astype(jnp.int32)) - 1
    cnt_per_grp = jax.ops.segment_sum(jnp.ones((e,), jnp.float32), gid,
                                      num_segments=e, indices_are_sorted=True)
    cnt = cnt_per_grp[gid]
    # --- candidate score (Eq. 13/14) ---------------------------------------
    score = cnt - gamma * w_self[node_s] * w_other_by_label[lab_s]
    best = jax.ops.segment_max(score, node_s, num_segments=n_side,
                               indices_are_sorted=True)
    best = jnp.where(jnp.isfinite(best), best, _NEG)
    # deterministic argmax: smallest label among maximizers
    is_best = score >= best[node_s]
    cand = jnp.where(is_best, lab_s, jnp.int32(n_labels))
    best_lab = jax.ops.segment_min(cand, node_s, num_segments=n_side,
                                   indices_are_sorted=True)
    # --- own-label score (own label is always a candidate) ----------------
    own_cnt = jax.ops.segment_sum(
        (cand_lab_of_edge == own_labels[node_of_edge]).astype(jnp.float32),
        node_of_edge, num_segments=n_side, indices_are_sorted=True)
    own_score = own_cnt - gamma * w_self * w_other_by_label[own_labels]
    move = (best > own_score) & (best_lab < n_labels)
    return jnp.where(move, best_lab, own_labels).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_users", "n_items"))
def lp_step(labels, edge_u, edge_v, edge_u_byv, edge_v_byv,
            w_users, w_items, gamma, *, n_users: int, n_items: int):
    """One full iteration = user half-step then item half-step."""
    n = n_users + n_items
    # users move (item labels fixed)
    item_labels = labels[n_users:]
    w_items_by_label = jax.ops.segment_sum(w_items, item_labels, num_segments=n)
    new_u = _half_step(edge_u, item_labels[edge_v], w_users,
                       w_items_by_label, labels[:n_users], gamma, n_users, n)
    labels = jnp.concatenate([new_u, item_labels])
    # items move (user labels fixed)
    w_users_by_label = jax.ops.segment_sum(w_users, new_u, num_segments=n)
    new_v = _half_step(edge_v_byv, new_u[edge_u_byv], w_items,
                       w_users_by_label, item_labels, gamma, n_items, n)
    return jnp.concatenate([new_u, new_v])


@functools.partial(jax.jit, static_argnames=("n_users", "n_items"))
def count_side_labels(labels, *, n_users: int, n_items: int):
    """(#distinct user labels, #distinct item labels) — fixed-shape."""
    n = n_users + n_items
    pu = jnp.zeros(n, jnp.int32).at[labels[:n_users]].set(1)
    pv = jnp.zeros(n, jnp.int32).at[labels[n_users:]].set(1)
    return pu.sum(), pv.sum()


def lp_solve(graph: BipartiteGraph, w_users: np.ndarray, w_items: np.ndarray,
             gamma: float, budget: int | None = None, max_iters: int = 8,
             init_labels: np.ndarray | None = None,
             ) -> Tuple[np.ndarray, int]:
    """Run side-synchronous LP until label budget met or max_iters.

    Returns (labels int32[n_nodes] in the shared id space, iters_run).
    Labels are NOT compacted; use Sketch/compact_labels downstream.
    """
    n_users, n_items = graph.n_users, graph.n_items
    eu = jnp.asarray(graph.edge_u)
    ev = jnp.asarray(graph.edge_v)
    perm = jnp.asarray(graph.perm_by_item)
    eu_byv, ev_byv = eu[perm], ev[perm]
    wu = jnp.asarray(w_users, jnp.float32)
    wv = jnp.asarray(w_items, jnp.float32)
    if init_labels is None:
        labels = jnp.arange(n_users + n_items, dtype=jnp.int32)
    else:
        labels = jnp.asarray(init_labels, jnp.int32)
    g = jnp.float32(gamma)
    it = 0
    prev = None
    for it in range(1, max_iters + 1):
        labels = lp_step(labels, eu, ev, eu_byv, ev_byv, wu, wv, g,
                         n_users=n_users, n_items=n_items)
        if budget is not None:
            ku, kv = count_side_labels(labels, n_users=n_users, n_items=n_items)
            if int(ku) + int(kv) <= budget:
                break
        lab_np = np.asarray(labels)
        if prev is not None and np.array_equal(lab_np, prev):
            break  # converged
        prev = lab_np
    return np.asarray(labels), it
