"""TPU-native balanced co-clustering solver (side-synchronous label propagation).

The paper's Algorithm 1 is a sequential greedy sweep: each node adopts the
neighbor label maximizing

    p(k) = |N(x) ∩ C_k|  -  gamma * w_x * W_other(k)            (Eq. 13/14)

where W_other(k) is the total weight of the *opposite-side* members of
cluster k. Sequential scatter-updates do not map to TPU, so we adapt the
sweep to the bipartite structure (DESIGN.md §3):

  * update ALL users in parallel holding item labels fixed, then all items
    holding user labels fixed. Each half-step is exact w.r.t. the other
    side's labels, and the alternation kills the 2-coloring oscillation of
    fully-synchronous LP.
  * p(k) decomposes into a pure gather/scan pass:
      - per-(node, candidate-label) edge counts via one two-key lax.sort
        + group-boundary arithmetic (exact integer cummax/cummin),
      - cluster weight sums W(k) via segment_sum,
      - per-node argmax via a segmented leftmost-argmax associative_scan
        (leftmost == smallest label, the deterministic tie-break) read
        out at searchsorted segment boundaries — no scatters.

Everything is fixed-shape (labels live in the shared id space [0, n_nodes))
so the whole step jits once per graph size.

The iteration loop itself is device-resident: a ``jax.lax.while_loop``
whose convergence (fixed point) and budget checks run on-device, so a
solve is ONE dispatch and ONE host transfer at the end — no per-sweep
``np.asarray`` round-trips. ``lp_solve_grid`` vmaps that loop over a
batch of gamma lanes (fit_gamma's grid search solves concurrently);
``lp_solve_hostloop`` keeps the original Python-loop semantics as the
benchmark reference the while_loop is validated bit-for-bit against.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import clock
from repro.obs.trace import get_tracer

from .graph import BipartiteGraph, pad_rung

__all__ = ["lp_solve", "lp_solve_grid", "lp_solve_hostloop", "lp_step",
           "count_side_labels", "solve_loop", "lp_cold_assign",
           "lp_solve_capped", "lp_solve_streamed"]

# plain float, not a device array: importing this module must never
# initialize the jax backend (dryrun sets XLA_FLAGS first)
_NEG = -3e38


def _half_step(node_of_edge, cand_lab_of_edge, w_self, w_other_by_label,
               own_labels, gamma, n_side, n_labels):
    """One parallel half-step for one side of the bipartite graph.

    node_of_edge: int32[E] updating-side endpoint, SORTED ascending.
    cand_lab_of_edge: int32[E] current label of the opposite endpoint.
    w_self: f32[n_side] weights of updating-side nodes.
    w_other_by_label: f32[n_labels] summed opposite-side weight per label.
    own_labels: int32[n_side] current labels of updating side.
    Returns new labels int32[n_side].
    """
    e = node_of_edge.shape[0]
    idx = jnp.arange(e, dtype=jnp.int32)
    # --- group edges by (node, candidate label) ---------------------------
    # ONE two-key lexicographic lax.sort (int32-safe; ~2.3x faster than
    # the seed's two stable argsorts + gathers — the sort is the dominant
    # cost of a sweep). Entries within an equal (node, label) group are
    # interchangeable, so every downstream value is bit-for-bit identical
    # to the seed ordering (tests assert it against _half_step_seed).
    node_s, lab_s = jax.lax.sort((node_of_edge, cand_lab_of_edge),
                                 num_keys=2)
    new_grp = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (node_s[1:] != node_s[:-1]) | (lab_s[1:] != lab_s[:-1])])
    is_last = jnp.concatenate([new_grp[1:], jnp.ones((1,), jnp.bool_)])
    # group sizes by boundary arithmetic (exact integers) instead of a
    # scatter-based segment_sum: per-edge group start via a running max
    # of start positions, group end via a reversed running min of ends
    start = jax.lax.cummax(jnp.where(new_grp, idx, 0))
    end = jnp.flip(jax.lax.cummin(jnp.flip(jnp.where(is_last, idx, e - 1))))
    cnt = (end - start + 1).astype(jnp.float32)
    # --- candidate score (Eq. 13/14) ---------------------------------------
    score = cnt - gamma * w_self[node_s] * w_other_by_label[lab_s]
    # deterministic argmax (smallest label among maximizers) in ONE
    # segmented leftmost-argmax scan — labels are ascending within a node
    # segment, so keeping the left element on score ties IS the smallest
    # maximizing label; per-node results sit at segment-end positions
    # recovered with searchsorted boundaries (no scatter)
    def _comb(a, b):
        n1, s1, l1 = a
        n2, s2, l2 = b
        keep = (n1 == n2) & (s1 >= s2)
        return n2, jnp.where(keep, s1, s2), jnp.where(keep, l1, l2)
    _, run_s, run_l = jax.lax.associative_scan(
        _comb, (node_s, score, lab_s))
    bounds = jnp.searchsorted(node_s,
                              jnp.arange(n_side + 1, dtype=jnp.int32))
    nonempty = bounds[1:] > bounds[:-1]
    last = jnp.maximum(bounds[1:] - 1, 0)
    best = jnp.where(nonempty, run_s[last], _NEG)
    best_lab = jnp.where(nonempty, run_l[last], jnp.int32(n_labels))
    # --- own-label score (own label is always a candidate) ----------------
    # exact int32 cumsum + boundary gathers; node_of_edge and node_s are
    # both sorted by node, so `bounds` above is exactly the node
    # boundaries of node_of_edge too — no second searchsorted
    own_hit = (cand_lab_of_edge == own_labels[node_of_edge]).astype(jnp.int32)
    cs = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(own_hit)])
    own_cnt = (cs[bounds[1:]] - cs[bounds[:-1]]).astype(jnp.float32)
    own_score = own_cnt - gamma * w_self * w_other_by_label[own_labels]
    move = (best > own_score) & (best_lab < n_labels)
    return jnp.where(move, best_lab, own_labels).astype(jnp.int32)


def _half_step_seed(node_of_edge, cand_lab_of_edge, w_self,
                    w_other_by_label, own_labels, gamma, n_side, n_labels):
    """The SEED's half-step grouping (two stable argsorts + gathers),
    frozen verbatim for the "jax_hostloop" benchmark reference so
    BENCH_cluster.json's before/after measures the pre-engine cost.
    Produces bit-for-bit the same labels as _half_step."""
    e = node_of_edge.shape[0]
    o1 = jnp.argsort(cand_lab_of_edge, stable=True)
    o2 = jnp.argsort(node_of_edge[o1], stable=True)
    order = o1[o2]
    node_s = node_of_edge[order]
    lab_s = cand_lab_of_edge[order]
    new_grp = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (node_s[1:] != node_s[:-1]) | (lab_s[1:] != lab_s[:-1])])
    gid = jnp.cumsum(new_grp.astype(jnp.int32)) - 1
    cnt_per_grp = jax.ops.segment_sum(jnp.ones((e,), jnp.float32), gid,
                                      num_segments=e, indices_are_sorted=True)
    cnt = cnt_per_grp[gid]
    score = cnt - gamma * w_self[node_s] * w_other_by_label[lab_s]
    best = jax.ops.segment_max(score, node_s, num_segments=n_side,
                               indices_are_sorted=True)
    best = jnp.where(jnp.isfinite(best), best, _NEG)
    is_best = score >= best[node_s]
    cand = jnp.where(is_best, lab_s, jnp.int32(n_labels))
    best_lab = jax.ops.segment_min(cand, node_s, num_segments=n_side,
                                   indices_are_sorted=True)
    own_cnt = jax.ops.segment_sum(
        (cand_lab_of_edge == own_labels[node_of_edge]).astype(jnp.float32),
        node_of_edge, num_segments=n_side, indices_are_sorted=True)
    own_score = own_cnt - gamma * w_self * w_other_by_label[own_labels]
    move = (best > own_score) & (best_lab < n_labels)
    return jnp.where(move, best_lab, own_labels).astype(jnp.int32)


def _lp_step_impl(half_step, labels, edge_u, edge_v, edge_u_byv, edge_v_byv,
                  w_users, w_items, gamma, n_users: int, n_items: int):
    """One full iteration = user half-step then item half-step."""
    n = n_users + n_items
    # users move (item labels fixed)
    item_labels = labels[n_users:]
    w_items_by_label = jax.ops.segment_sum(w_items, item_labels, num_segments=n)
    new_u = half_step(edge_u, item_labels[edge_v], w_users,
                      w_items_by_label, labels[:n_users], gamma, n_users, n)
    # items move (user labels fixed)
    w_users_by_label = jax.ops.segment_sum(w_users, new_u, num_segments=n)
    new_v = half_step(edge_v_byv, new_u[edge_u_byv], w_items,
                      w_users_by_label, item_labels, gamma, n_items, n)
    return jnp.concatenate([new_u, new_v])


@functools.partial(jax.jit, static_argnames=("n_users", "n_items"))
def lp_step(labels, edge_u, edge_v, edge_u_byv, edge_v_byv,
            w_users, w_items, gamma, *, n_users: int, n_items: int):
    return _lp_step_impl(_half_step, labels, edge_u, edge_v, edge_u_byv,
                         edge_v_byv, w_users, w_items, gamma, n_users,
                         n_items)


@functools.partial(jax.jit, static_argnames=("n_users", "n_items"))
def _lp_step_seed(labels, edge_u, edge_v, edge_u_byv, edge_v_byv,
                  w_users, w_items, gamma, *, n_users: int, n_items: int):
    return _lp_step_impl(_half_step_seed, labels, edge_u, edge_v, edge_u_byv,
                         edge_v_byv, w_users, w_items, gamma, n_users,
                         n_items)


def _count_side(labels, n_users: int, n_items: int):
    """Trace-safe (#user labels, #item labels) pair — fixed-shape."""
    n = n_users + n_items
    pu = jnp.zeros(n, jnp.int32).at[labels[:n_users]].set(1)
    pv = jnp.zeros(n, jnp.int32).at[labels[n_users:]].set(1)
    return pu.sum(), pv.sum()


@functools.partial(jax.jit, static_argnames=("n_users", "n_items"))
def count_side_labels(labels, *, n_users: int, n_items: int):
    """(#distinct user labels, #distinct item labels) — fixed-shape."""
    return _count_side(labels, n_users, n_items)


def solve_loop(step, labels, budget, max_iters, *, n_users: int,
               n_items: int):
    """Shared device-resident solve loop: run ``step`` (one full sweep,
    labels -> labels) under a lax.while_loop until budget, convergence
    or max_iters. Used by the single-device, vmapped-grid AND sharded
    solvers so the termination semantics live in exactly one place.

    budget == 0 disables the budget early-exit. Fixed-point semantics
    match the original host loop exactly: the sweep producing labels
    identical to the previous sweep's is still counted (it is the sweep
    that DETECTS convergence), and the budget is checked after each
    sweep so a warm-start seed already within budget still feels the
    current gamma at least once.
    """
    def cond(state):
        _, it, done = state
        return (~done) & (it < max_iters)

    def body(state):
        labels, it, _ = state
        new = step(labels)
        ku, kv = _count_side(new, n_users, n_items)
        within = (budget > 0) & (ku + kv <= budget)
        converged = jnp.all(new == labels)
        return new, it + jnp.int32(1), within | converged

    state = (labels, jnp.int32(0), jnp.bool_(False))
    labels, it, _ = jax.lax.while_loop(cond, body, state)
    return labels, it


def _solve_while(labels, eu, ev, eu_byv, ev_byv, wu, wv, gamma, budget,
                 max_iters, *, n_users: int, n_items: int):
    """solve_loop over the single-device lp_step (traced; gamma/budget/
    max_iters are operands so one compile covers the whole gamma grid)."""
    def step(labels):
        return lp_step(labels, eu, ev, eu_byv, ev_byv, wu, wv, gamma,
                       n_users=n_users, n_items=n_items)
    return solve_loop(step, labels, budget, max_iters, n_users=n_users,
                      n_items=n_items)


_solve_jit = jax.jit(_solve_while, static_argnames=("n_users", "n_items"))


# grid mode: vmap over gamma lanes (labels broadcast or per-lane); the
# batched while_loop runs until every lane is done, masking finished
# lanes, so each lane's result is bit-for-bit the single-lane result.
@functools.partial(jax.jit, static_argnames=("n_users", "n_items"))
def _solve_grid_jit(lab0, eu, ev, eu_byv, ev_byv, wu, wv, gammas, budget,
                    max_iters, *, n_users: int, n_items: int):
    f = functools.partial(_solve_while, n_users=n_users, n_items=n_items)
    return jax.vmap(
        f, in_axes=(0, None, None, None, None, None, None, 0, None, None),
    )(lab0, eu, ev, eu_byv, ev_byv, wu, wv, gammas, budget, max_iters)


def _device_inputs(graph: BipartiteGraph, w_users, w_items):
    eu = jnp.asarray(graph.edge_u)
    ev = jnp.asarray(graph.edge_v)
    perm = jnp.asarray(graph.perm_by_item)
    return (eu, ev, eu[perm], ev[perm],
            jnp.asarray(w_users, jnp.float32),
            jnp.asarray(w_items, jnp.float32))


def _init_labels(graph: BipartiteGraph, init_labels):
    if init_labels is None:
        return jnp.arange(graph.n_nodes, dtype=jnp.int32)
    return jnp.asarray(init_labels, jnp.int32)


def lp_solve(graph: BipartiteGraph, w_users: np.ndarray, w_items: np.ndarray,
             gamma: float, budget: int | None = None, max_iters: int = 8,
             init_labels: np.ndarray | None = None,
             ) -> Tuple[np.ndarray, int]:
    """Run side-synchronous LP until label budget met or max_iters.

    Returns (labels int32[n_nodes] in the shared id space, iters_run).
    Labels are NOT compacted; use Sketch/compact_labels downstream.
    """
    eu, ev, eu_byv, ev_byv, wu, wv = _device_inputs(graph, w_users, w_items)
    labels, it = _solve_jit(
        _init_labels(graph, init_labels), eu, ev, eu_byv, ev_byv, wu, wv,
        jnp.float32(gamma), jnp.int32(0 if budget is None else budget),
        jnp.int32(max_iters), n_users=graph.n_users, n_items=graph.n_items)
    return np.asarray(labels), int(it)


def lp_solve_grid(graph: BipartiteGraph, w_users, w_items, gammas,
                  budget: int | None = None, max_iters: int = 8,
                  init_labels: np.ndarray | None = None,
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Solve a whole gamma grid concurrently (vmapped while_loop).

    gammas: float[L]. init_labels: None (singletons), [n] (one seed for
    every lane) or [L, n] (per-lane seeds).
    Returns (labels int32[L, n_nodes], iters int32[L]).
    """
    gam = jnp.asarray(np.asarray(gammas, np.float32))
    lanes = gam.shape[0]
    eu, ev, eu_byv, ev_byv, wu, wv = _device_inputs(graph, w_users, w_items)
    init = np.asarray(init_labels, np.int32) if init_labels is not None \
        else None
    if init is None or init.ndim == 1:
        lab0 = jnp.broadcast_to(_init_labels(graph, init),
                                (lanes, graph.n_nodes))
    else:
        lab0 = jnp.asarray(init)
    labels, iters = _solve_grid_jit(
        lab0, eu, ev, eu_byv, ev_byv, wu, wv, gam,
        jnp.int32(0 if budget is None else budget), jnp.int32(max_iters),
        n_users=graph.n_users, n_items=graph.n_items)
    return np.asarray(labels), np.asarray(iters)


# ---------------------------------------------------------------------------
# capacity-padded solve: one compiled program across a growing graph
# ---------------------------------------------------------------------------
def lp_solve_capped(graph: BipartiteGraph, w_users, w_items, gamma: float,
                    budget: int | None = None, max_iters: int = 8,
                    init_labels: np.ndarray | None = None,
                    caps: dict | None = None) -> Tuple[np.ndarray, int]:
    """``lp_solve`` over inputs padded to capacity rungs — so a stream
    of growing graphs (repro.stream refreshes) reuses ONE compiled
    while_loop program until a rung is outgrown, instead of retracing
    on every growth.

    The padding is exact, not approximate — real labels come out
    BIT-FOR-BIT equal to the unpadded solve (tests/test_stream.py):

      * pad users/items carry weight 0 and one shared pad label P
        (the last padded id, above every real id): they are nobody's
        neighbor, so no real node can ever see or adopt P;
      * pad edges connect pad user <-> pad item, appended after both
        sorted runs (ids are the largest, so sortedness holds); their
        candidate label IS the pad nodes' own label, so pad nodes sit
        at a fixed point and contribute weight-0 terms elsewhere;
      * the budget early-exit counts P once per padded side, so the
        on-device budget is compensated by exactly that much.

    ``caps`` may fix {"n_users", "n_items", "n_edges"} rungs (values
    are raised to at least the real sizes); None falls back to the
    plain solve.
    """
    if caps is None:
        return lp_solve(graph, w_users, w_items, gamma, budget, max_iters,
                        init_labels=init_labels)
    nu, nv, e = graph.n_users, graph.n_items, graph.n_edges
    cu = _pad_rung(max(int(caps.get("n_users") or 0), nu))
    cv = _pad_rung(max(int(caps.get("n_items") or 0), nv))
    ce = _pad_rung(max(int(caps.get("n_edges") or 0), e, 1))
    if (cu, cv, ce) == (nu, nv, e):
        return lp_solve(graph, w_users, w_items, gamma, budget, max_iters,
                        init_labels=init_labels)
    if ce > e:        # pad edges need PAD endpoints on both sides — a
        cu = cu if cu > nu else 2 * cu   # real endpoint would see the
        cv = cv if cv > nv else 2 * cv   # pad label as a candidate
    pad_label = cu + cv - 1

    def pad1(a, size, fill, dtype):
        out = np.full(size, fill, dtype)
        out[:a.shape[0]] = a
        return out

    eu = pad1(graph.edge_u, ce, cu - 1, np.int32)
    ev = pad1(graph.edge_v, ce, cv - 1, np.int32)
    eu_byv = pad1(graph.edge_u[graph.perm_by_item], ce, cu - 1, np.int32)
    ev_byv = pad1(graph.edge_v[graph.perm_by_item], ce, cv - 1, np.int32)
    wu = pad1(np.asarray(w_users, np.float32), cu, 0, np.float32)
    wv = pad1(np.asarray(w_items, np.float32), cv, 0, np.float32)
    if init_labels is None:
        init_u = np.arange(nu, dtype=np.int32)
        init_v = np.arange(nu, nu + nv, dtype=np.int32)
    else:
        init = np.asarray(init_labels, np.int32)
        init_u, init_v = init[:nu], init[nu:]
    lab = np.full(cu + cv, pad_label, np.int32)
    lab[:nu] = init_u
    lab[cu:cu + nv] = init_v
    pad_sides = int(cu > nu) + int(cv > nv)
    budget_p = 0 if budget is None else int(budget) + pad_sides
    labels, it = _solve_jit(
        jnp.asarray(lab), jnp.asarray(eu), jnp.asarray(ev),
        jnp.asarray(eu_byv), jnp.asarray(ev_byv), jnp.asarray(wu),
        jnp.asarray(wv), jnp.float32(gamma), jnp.int32(budget_p),
        jnp.int32(max_iters), n_users=cu, n_items=cv)
    labels = np.asarray(labels)
    return np.concatenate([labels[:nu], labels[cu:cu + nv]]), int(it)


# ---------------------------------------------------------------------------
# cold-start assignment: one half-step over only the new nodes' edges
# ---------------------------------------------------------------------------
# the shape ladder cold assigns and capped solves compile against,
# mirroring BatchDispatcher's bucket idea — a replay stream of arbitrary
# arrival sizes compiles O(log^2) programs, not one per shape
_pad_rung = pad_rung


@functools.partial(jax.jit, static_argnames=("n_side", "n_labels"))
def _cold_half_jit(node, cand_idx, opp_labels, w_self, w_opp, own, gamma,
                   *, n_side: int, n_labels: int):
    """One padded half-step for the cold nodes of one side: the cluster
    weight totals (volume-balance term) are computed over ALL
    opposite-side nodes, but the sort/scan passes only run over the cold
    nodes' incident edges."""
    w_by_label = jax.ops.segment_sum(w_opp, opp_labels,
                                     num_segments=n_labels)
    return _half_step(node, opp_labels[cand_idx], w_self, w_by_label, own,
                      gamma, n_side, n_labels)


def _cold_side(node_tail, opp_tail, opp_labels, w_self_side, own_side,
               w_opp_full, gamma, n_new: int, n_labels: int) -> np.ndarray:
    """Pad one side's cold tail onto the shape ladder and run the
    half-step. node_tail is 0-based over the n_new cold nodes and sorted
    (the cold nodes are an index-suffix, so their edges are a contiguous
    tail of the corresponding sorted edge orientation). Pad edges hang
    off a phantom node (id n_pad), so real rows are untouched. The
    opposite-side arrays and the label space ride the ladder too — a
    growing replay stream would otherwise recompile on every ``grow``.
    """
    n_pad = _pad_rung(n_new)
    e_pad = _pad_rung(node_tail.size)
    node = np.full(e_pad, n_pad, np.int32)
    node[:node_tail.size] = node_tail
    cand = np.zeros(e_pad, np.int32)
    cand[:opp_tail.size] = opp_tail
    w_self = np.zeros(n_pad + 1, np.float32)
    w_self[:n_new] = w_self_side
    own = np.zeros(n_pad + 1, np.int32)
    own[:n_new] = own_side
    opp_pad = _pad_rung(opp_labels.size)
    opp_lab = np.zeros(opp_pad, np.int32)           # pad label 0 ...
    opp_lab[:opp_labels.size] = opp_labels
    w_opp = np.zeros(opp_pad, np.float32)           # ... carries 0 weight
    w_opp[:w_opp_full.size] = w_opp_full
    out = _cold_half_jit(jnp.asarray(node), jnp.asarray(cand),
                         jnp.asarray(opp_lab), jnp.asarray(w_self),
                         jnp.asarray(w_opp), jnp.asarray(own),
                         jnp.float32(gamma), n_side=n_pad + 1,
                         n_labels=_pad_rung(n_labels))
    return np.asarray(out)[:n_new]


def _cand_edge_mask(node_tail: np.ndarray, edge_lab: np.ndarray,
                    flat: np.ndarray, indptr: np.ndarray,
                    n_labels: int) -> np.ndarray:
    """bool[E_tail]: which cold edges carry a candidate label that
    survives pruning. ``flat``/``indptr`` are per-cold-node candidate
    label lists (CSR over the 0-based cold nodes, labels SORTED within
    each node's slice — core.candidates emits exactly this). Vectorized
    membership: fuse (node, label) into one int64 key and searchsorted
    the fused candidate keys (ascending because nodes are grouped in
    order and labels sorted within a node)."""
    if flat.size == 0 or node_tail.size == 0:
        return np.zeros(node_tail.shape, bool)
    m = np.int64(n_labels) + 1
    reps = np.diff(np.asarray(indptr, np.int64))
    ckeys = np.repeat(np.arange(reps.size, dtype=np.int64), reps) * m \
        + np.asarray(flat, np.int64)
    keys = node_tail.astype(np.int64) * m + np.asarray(edge_lab, np.int64)
    pos = np.minimum(np.searchsorted(ckeys, keys), ckeys.size - 1)
    return ckeys[pos] == keys


def lp_cold_assign(graph: BipartiteGraph, labels, w_users, w_items,
                   gamma: float, n_new_users: int = 0,
                   n_new_items: int = 0,
                   cand_labels: dict | None = None) -> np.ndarray:
    """Place brand-new users/items (index suffixes of their sides) into
    the existing partition with ONE device-resident LP half-step each,
    over only their incident edges.

    The score is exactly Eq. 13/14 — neighbor-label counts minus the
    gamma-weighted opposite-side cluster volume — so the balance term is
    retained: without it every cold node would fall into the hottest
    cluster touching any of its neighbors. A cold node whose best
    candidate scores no better than staying alone keeps its (fresh
    singleton) label, i.e. it may legitimately found a new cluster that
    the next ``refresh`` consolidates.

    ``labels`` must already be grown to the new node universe, with the
    cold nodes holding fresh unique labels (``grow_labels``). Users are
    assigned first (item labels fixed), then items see the updated user
    labels — the same alternation order as a solver sweep. Inputs are
    padded onto a power-of-two shape ladder so replay streams of
    arbitrary arrival sizes compile a bounded set of programs. Returns
    the updated labels (host int32[n_nodes]); old nodes never move.

    ``cand_labels`` (optional) prunes the candidate universe per cold
    node: {"user"/"item": (flat, indptr)} CSR lists of allowed labels
    (sorted within each node's slice — ``core.candidates`` builds
    these). Edges whose neighbor label is outside the node's list are
    dropped BEFORE the half-step, so the sorted/padded edge tail — the
    O(labels-scored) work — shrinks to O(candidates). The node's own
    (fresh singleton) label always stays a candidate, so a pruned cold
    node can still found a new cluster; and since no opposite-side node
    carries a fresh singleton label, dropping edges never perturbs the
    own-score term. Exactness then reduces to candidate recall: if the
    exact argmax label is in the list, the assignment is identical.
    """
    nu, nv, n = graph.n_users, graph.n_items, graph.n_nodes
    lab = np.array(labels, dtype=np.int32, copy=True)
    if lab.shape[0] != n:
        raise ValueError(f"labels must cover the grown universe: "
                         f"{lab.shape[0]} != {n} nodes")
    if not (0 <= n_new_users <= nu and 0 <= n_new_items <= nv):
        raise ValueError("n_new_users/n_new_items out of range")
    if n_new_users == 0 and n_new_items == 0:
        return lab
    wu = np.asarray(w_users, np.float32)
    wv = np.asarray(w_items, np.float32)

    def prune(side, node_tail, opp_tail, opp_lab, own_lab):
        if cand_labels is None or side not in cand_labels:
            return node_tail, opp_tail
        flat, indptr = cand_labels[side]
        edge_lab = opp_lab[opp_tail]
        keep = _cand_edge_mask(node_tail, edge_lab, np.asarray(flat),
                               np.asarray(indptr), n)
        keep |= edge_lab == own_lab[node_tail]
        return node_tail[keep], opp_tail[keep]

    if n_new_users:
        u0 = nu - n_new_users
        lo = int(np.searchsorted(graph.edge_u, u0))
        node_tail = (graph.edge_u[lo:] - u0).astype(np.int32)
        node_tail, opp_tail = prune("user", node_tail, graph.edge_v[lo:],
                                    lab[nu:], lab[u0:nu])
        lab[u0:nu] = _cold_side(
            node_tail, opp_tail,
            lab[nu:], wu[u0:], lab[u0:nu], wv, gamma, n_new_users, n)
    if n_new_items:
        v0 = nv - n_new_items
        ev_byv, eu_byv = graph.edges_by_item()
        lo = int(np.searchsorted(ev_byv, v0))
        node_tail = (ev_byv[lo:] - v0).astype(np.int32)
        node_tail, opp_tail = prune("item", node_tail, eu_byv[lo:],
                                    lab[:nu], lab[nu + v0:])
        lab[nu + v0:] = _cold_side(
            node_tail, opp_tail,
            lab[:nu], wv[v0:], lab[nu + v0:], wu, gamma, n_new_items, n)
    return lab


# ---------------------------------------------------------------------------
# streamed edge-block solve: million-node graphs without device-resident
# edge lists
# ---------------------------------------------------------------------------
# The half-step is a per-node reduction over that node's incident edges:
# group counts, candidate argmax and own-label counts never mix edges of
# different nodes, and the only cross-node coupling — the per-label
# opposite-side weight totals W(k) — is an O(n) quantity computed from
# the LABELS, not the edges. So the edge list can stay host-side and be
# swept in fixed-size node-aligned blocks (graph.edge_block_bounds): one
# compiled per-block program runs the same sort/scan passes as
# ``_half_step`` over its block and scatters each finished node's
# (best score, best label, own count) into donated [n_side]
# accumulators; a commit program applies the move rule once every block
# has been accumulated. Node alignment is what keeps this exact: a
# node's (node, label) groups are complete within its block, so every
# count, score and tie-break is bit-for-bit the in-memory value for ANY
# nominal block size (tests/test_scale.py sweeps 1 edge .. all edges).
# Accumulate-then-commit also preserves Algorithm 1's side-synchronous
# order: no user label changes until every user block has been scored
# against the SAME fixed item labels (and vice versa), exactly like the
# in-memory half-step.
def _stream_block_impl(acc_best, acc_lab, acc_own, node_g, opp_idx,
                       opp_labels, w_self, w_other_by_label, own_labels,
                       gamma, *, n_side: int, n_labels: int):
    """Score one node-aligned edge block and fold the finished nodes'
    results into the accumulators.

    node_g: int32[B] global updating-side ids, sorted ascending; pad
      entries carry the sentinel id ``n_side`` (sorts to the end, and
      every scatter at an out-of-bounds index is dropped).
    opp_idx: int32[B] opposite-side endpoint (pad 0 — harmless, the pad
      rows never scatter).
    """
    b = node_g.shape[0]
    idx = jnp.arange(b, dtype=jnp.int32)
    cand = opp_labels[opp_idx]
    # identical group machinery to _half_step, over the block only
    node_s, lab_s = jax.lax.sort((node_g, cand), num_keys=2)
    new_grp = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (node_s[1:] != node_s[:-1]) | (lab_s[1:] != lab_s[:-1])])
    is_last = jnp.concatenate([new_grp[1:], jnp.ones((1,), jnp.bool_)])
    start = jax.lax.cummax(jnp.where(new_grp, idx, 0))
    end = jnp.flip(jax.lax.cummin(jnp.flip(jnp.where(is_last, idx, b - 1))))
    cnt = (end - start + 1).astype(jnp.float32)
    score = cnt - gamma * w_self[node_s] * w_other_by_label[lab_s]

    def _comb(a, c):
        n1, s1, l1 = a
        n2, s2, l2 = c
        keep = (n1 == n2) & (s1 >= s2)
        return n2, jnp.where(keep, s1, s2), jnp.where(keep, l1, l2)

    _, run_s, run_l = jax.lax.associative_scan(
        _comb, (node_s, score, lab_s))
    # per-node readout at the last edge of each node segment, scattered
    # straight into the accumulators (pads and interior edges target the
    # out-of-bounds sentinel and are dropped)
    new_node = jnp.concatenate([
        jnp.ones((1,), jnp.bool_), node_s[1:] != node_s[:-1]])
    last_node = jnp.concatenate([new_node[1:], jnp.ones((1,), jnp.bool_)])
    tgt = jnp.where(last_node, node_s, jnp.int32(n_side))
    acc_best = acc_best.at[tgt].set(run_s)
    acc_lab = acc_lab.at[tgt].set(run_l)
    # own-label counts: exact int32 cumsum over the node's block-local run
    own_hit = (lab_s == own_labels[node_s]).astype(jnp.int32)
    cs = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(own_hit)])
    node_start = jax.lax.cummax(jnp.where(new_node, idx, 0))
    own_cnt = (cs[idx + 1] - cs[node_start]).astype(jnp.float32)
    acc_own = acc_own.at[tgt].set(own_cnt)
    return acc_best, acc_lab, acc_own


def _stream_commit_impl(acc_best, acc_lab, acc_own, w_self,
                        w_other_by_label, own_labels, gamma, *,
                        n_labels: int):
    """The move rule of ``_half_step``, applied once per half-step after
    every block has been accumulated. Nodes no block touched (edgeless)
    keep acc_best == _NEG / acc_lab == n_labels and never move."""
    own_score = acc_own - gamma * w_self * w_other_by_label[own_labels]
    move = (acc_best > own_score) & (acc_lab < n_labels)
    return jnp.where(move, acc_lab, own_labels).astype(jnp.int32)


@functools.cache
def _stream_jits(donate: bool):
    """(block, commit, w_by_label) jitted programs; accumulator donation
    only where the backend honors it (donating on CPU just warns)."""
    kw = {"donate_argnums": (0, 1, 2)} if donate else {}
    block = functools.partial(jax.jit, static_argnames=("n_side", "n_labels"),
                              **kw)(_stream_block_impl)
    commit = functools.partial(jax.jit, static_argnames=("n_labels",))(
        _stream_commit_impl)

    @functools.partial(jax.jit, static_argnames=("n",))
    def w_by_label(w, labels, *, n):
        return jax.ops.segment_sum(w, labels, num_segments=n)

    return block, commit, w_by_label


def _stream_plan(graph: BipartiteGraph, block_edges: int):
    """Host-side sweep plan: padded numpy (node, opp) blocks for both
    edge orientations. Label-independent, so one plan serves every
    sweep of a solve (and is memoized on the graph for re-solves)."""
    def side_blocks(node_arr, opp_arr, bounds, n_side):
        widths = np.diff(bounds)
        pad = pad_rung(int(widths.max()) if widths.size else 1)
        out = []
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            lo, hi = int(lo), int(hi)
            nb = np.full(pad, n_side, np.int32)
            ob = np.zeros(pad, np.int32)
            nb[:hi - lo] = node_arr[lo:hi]
            ob[:hi - lo] = opp_arr[lo:hi]
            out.append((nb, ob))
        return out, pad

    def build():
        ev_byv, eu_byv = graph.edges_by_item()
        ub, upad = side_blocks(graph.edge_u, graph.edge_v,
                               graph.edge_block_bounds("user", block_edges),
                               graph.n_users)
        vb, vpad = side_blocks(ev_byv, eu_byv,
                               graph.edge_block_bounds("item", block_edges),
                               graph.n_items)
        return {"user": (ub, upad), "item": (vb, vpad)}

    return graph._memo(f"stream_plan/{int(block_edges)}", build)


def _streamed_half(blocks, n_side: int, n_labels: int, opp_labels, w_self,
                   w_by_label, own_labels, gamma, jits):
    block_fn, commit_fn, _ = jits
    acc_best = jnp.full((n_side,), _NEG, jnp.float32)
    acc_lab = jnp.full((n_side,), n_labels, jnp.int32)
    acc_own = jnp.zeros((n_side,), jnp.float32)
    nxt = (jax.device_put(blocks[0][0]), jax.device_put(blocks[0][1])) \
        if blocks else None
    tracer = get_tracer()
    parent = tracer.current()
    for i in range(len(blocks)):
        cur = nxt
        t0 = clock.now()
        out = block_fn(acc_best, acc_lab, acc_own, cur[0], cur[1],
                       opp_labels, w_self, w_by_label, own_labels, gamma,
                       n_side=n_side, n_labels=n_labels)
        if i + 1 < len(blocks):
            # enqueue the next block's H2D copy while the current block
            # computes (dispatch is async) — the double buffer
            nxt = (jax.device_put(blocks[i + 1][0]),
                   jax.device_put(blocks[i + 1][1]))
        acc_best, acc_lab, acc_own = out
        if parent is not None and parent.sampled:
            # dispatch is async: this spans enqueue (+ the overlapped
            # H2D of the next block), not device completion — the sweep
            # span above it carries the blocking wall time
            tracer.record_span("edge_block", t0, clock.now(),
                               parent=parent, block=i)
    return commit_fn(acc_best, acc_lab, acc_own, w_self, w_by_label,
                     own_labels, gamma, n_labels=n_labels)


def _peak_device_bytes() -> int | None:
    """Allocator-reported peak bytes where the backend exposes it
    (TPU/GPU); None on backends without memory_stats (CPU)."""
    try:
        ms = jax.local_devices()[0].memory_stats()
        if ms and ms.get("peak_bytes_in_use"):
            return int(ms["peak_bytes_in_use"])
    except Exception:
        pass
    return None


def lp_solve_streamed(graph: BipartiteGraph, w_users, w_items, gamma: float,
                      budget: int | None = None, max_iters: int = 8,
                      init_labels: np.ndarray | None = None,
                      block_edges: int = 1 << 20,
                      stats: dict | None = None) -> Tuple[np.ndarray, int]:
    """``lp_solve`` without ever materializing the edge list on device.

    Edges stay host-side numpy; each sweep streams node-aligned blocks
    of at most ``block_edges`` edges through one compiled per-block
    program (donated accumulators, next block's H2D copy double-buffered
    behind the current block's compute). Device residency is O(n +
    block), not O(E). Labels are BIT-FOR-BIT equal to ``lp_solve`` for
    any block size (node alignment keeps per-node groups block-local;
    the per-label weight totals are computed from labels with the same
    segment_sum; the commit applies the identical move rule), and the
    sweep/budget/convergence semantics replicate ``solve_loop`` —
    including counting the converged-detect sweep.

    ``stats`` (optional dict) is filled with the sweep telemetry the
    scaling ladder records: blocks per side, padded block length, per-
    sweep seconds, blocks/s, and peak device bytes where the backend
    reports them (else a documented residency estimate).
    """
    n_users, n_items = graph.n_users, graph.n_items
    n = n_users + n_items
    plan = _stream_plan(graph, int(block_edges))
    jits = _stream_jits(jax.default_backend() != "cpu")
    _, _, w_by_label_fn = jits
    wu = jnp.asarray(np.asarray(w_users, np.float32))
    wv = jnp.asarray(np.asarray(w_items, np.float32))
    labels = _init_labels(graph, init_labels)
    g = jnp.float32(gamma)
    bud = 0 if budget is None else int(budget)
    it = 0
    done = False
    sweep_s = []
    tracer = get_tracer()
    while not done and it < max_iters:
        t0 = clock.now()
        # live span (child of the engine's ambient "cluster_solve" when
        # one is open, else its own root): the per-block edge_block
        # spans in _streamed_half nest under it
        with tracer.span("lp_sweep", sweep=it) as sweep_sp:
            item_labels = labels[n_users:]
            w_items_by = w_by_label_fn(wv, item_labels, n=n)
            new_u = _streamed_half(plan["user"][0], n_users, n,
                                   item_labels, wu, w_items_by,
                                   labels[:n_users], g, jits)
            w_users_by = w_by_label_fn(wu, new_u, n=n)
            new_v = _streamed_half(plan["item"][0], n_items, n, new_u,
                                   wv, w_users_by, item_labels, g, jits)
            new = jnp.concatenate([new_u, new_v])
            ku, kv = count_side_labels(new, n_users=n_users,
                                       n_items=n_items)
            within = bud > 0 and int(ku) + int(kv) <= bud
            converged = bool(jnp.array_equal(new, labels))
            new.block_until_ready()
            sweep_sp.set(converged=converged)
        sweep_s.append(clock.now() - t0)
        labels = new
        it += 1
        done = within or converged
    if stats is not None:
        nb = len(plan["user"][0]) + len(plan["item"][0])
        upad, vpad = plan["user"][1], plan["item"][1]
        total = sum(sweep_s)
        peak = _peak_device_bytes()
        # residency estimate: labels old+new [n], three accumulators +
        # weights + own labels per side, one [n] weight-total vector,
        # and 2x double-buffered (node, opp) int32 block pair
        est = 4 * (2 * n + 5 * max(n_users, n_items) + n
                   + 4 * max(upad, vpad))
        stats.update(
            n_blocks_user=len(plan["user"][0]),
            n_blocks_item=len(plan["item"][0]),
            block_pad_user=int(upad), block_pad_item=int(vpad),
            block_edges=int(block_edges), sweeps=int(it),
            sweep_s=[round(s, 4) for s in sweep_s],
            sweep_ms=round(min(sweep_s) * 1e3, 2) if sweep_s else 0.0,
            blocks_per_s=round(it * nb / total, 2) if total > 0 else 0.0,
            peak_device_bytes=peak if peak is not None else est,
            peak_bytes_source="memory_stats" if peak is not None
            else "residency_estimate")
    return np.asarray(labels), it


def lp_solve_hostloop(graph: BipartiteGraph, w_users, w_items, gamma: float,
                      budget: int | None = None, max_iters: int = 8,
                      init_labels: np.ndarray | None = None,
                      ) -> Tuple[np.ndarray, int]:
    """The SEED's host-driven loop, frozen: one dispatch per sweep (with
    the original two-argsort half-step) plus a full labels transfer for
    the convergence check. Kept as the benchmark reference
    (BENCH_cluster.json's before/after) and as the oracle the
    device-resident loop is tested bit-for-bit against."""
    n_users, n_items = graph.n_users, graph.n_items
    eu, ev, eu_byv, ev_byv, wu, wv = _device_inputs(graph, w_users, w_items)
    labels = _init_labels(graph, init_labels)
    g = jnp.float32(gamma)
    it = 0
    prev = None
    for it in range(1, max_iters + 1):
        labels = _lp_step_seed(labels, eu, ev, eu_byv, ev_byv, wu, wv, g,
                               n_users=n_users, n_items=n_items)
        if budget is not None:
            ku, kv = count_side_labels(labels, n_users=n_users,
                                       n_items=n_items)
            if int(ku) + int(kv) <= budget:
                break
        lab_np = np.asarray(labels)
        if prev is not None and np.array_equal(lab_np, prev):
            break  # converged
        prev = lab_np
    return np.asarray(labels), it
