"""One-bit-minhash candidate pruning: sublinear-in-labels assignment.

Cold-start assignment and warm refreshes score a node against the
labels of its neighbors — O(degree) candidate labels per node, but on
large graphs with many live clusters that is still "every label any
neighbor touches". Saec-style similarity hashing (PAPERS.md) makes the
per-node candidate universe O(bucket): nodes with Jaccard-similar
neighborhoods collide in LSH buckets, and the labels of a node's
bucket-mates are the clusters it could plausibly join. This module is
the numpy-vectorized adaptation of the classic bucket-table +
prefix-sum query planner (SNIPPETS.md Snippet 2): band codes via
one-bit minhash, per-band SORTED code tables instead of dicts, and one
repeat/cumsum plan that gathers every query's bucket slices without a
Python loop over queries.

Scheme: H = n_bands * rows_per_band hash functions. For each function,
a node's signature is the minimum multiplicative hash over its
neighborhood; one-bit minhash keeps a single mixed bit of that minimum,
and ``rows_per_band`` bits pack into a band code. Two nodes with
neighborhood Jaccard J agree on a bit with probability (1 + J) / 2, so
they collide in a band with ((1 + J) / 2)^rows_per_band and in at least
one of n_bands bands with 1 - (1 - p)^n_bands — the usual S-curve; the
defaults (16 bands x 4 rows) put ~0.96 collision probability at
J = 0.3, and recall of the true argmax LABEL is higher still because a
cluster is recalled if ANY of its members collides.

Exactness contract: pruning never changes scores, only which labels are
scored (``solver_jax.lp_cold_assign(cand_labels=...)`` drops edges
whose label is outside the set). If the exact argmax label is in the
candidate set — the measured recall — the assignment is bitwise the
exact one.
"""
from __future__ import annotations

import numpy as np

from .graph import BipartiteGraph

__all__ = ["MinHashIndex", "cold_candidate_sets", "prune_graph",
           "candidate_recall"]


def _csr_unique_pairs(q_of: np.ndarray, vals: np.ndarray, n_q: int,
                      n_vals: int):
    """Dedup (query, value) pairs and CSR-ify: returns (flat, indptr)
    with values sorted ascending within each query's slice."""
    if q_of.size == 0:
        return (np.empty(0, np.int64),
                np.zeros(n_q + 1, np.int64))
    m = np.int64(n_vals) + 1
    keys = np.unique(q_of.astype(np.int64) * m + vals.astype(np.int64))
    q = keys // m
    flat = keys % m
    indptr = np.zeros(n_q + 1, np.int64)
    np.cumsum(np.bincount(q, minlength=n_q), out=indptr[1:])
    return flat, indptr


class MinHashIndex:
    """Banded one-bit-minhash index over node neighborhoods.

    ``fit`` hashes the indexed nodes' neighborhoods into per-band sorted
    code tables; ``query`` plans every query's bucket gathers with one
    prefix-sum pass and returns deduped candidate-node CSR lists.
    ``max_per_band`` caps how many bucket-mates a single band may
    contribute per query (degenerate mega-buckets — e.g. many identical
    tiny neighborhoods — would otherwise make "candidates" mean
    "everyone"); the cap keeps per-query work O(n_bands * cap).
    """

    def __init__(self, n_bands: int = 16, rows_per_band: int = 4,
                 seed: int = 0, max_per_band: int = 32):
        if n_bands < 1 or rows_per_band < 1 or rows_per_band > 16:
            raise ValueError("need n_bands >= 1, 1 <= rows_per_band <= 16")
        self.n_bands = int(n_bands)
        self.rows_per_band = int(rows_per_band)
        self.max_per_band = int(max_per_band)
        rng = np.random.default_rng(seed)
        # odd multipliers: bijective over Z/2^64, so the min picks a
        # uniform pseudo-random neighborhood element per hash
        self._mults = rng.integers(
            1, 1 << 62, size=self.n_bands * self.rows_per_band,
            dtype=np.uint64) * np.uint64(2) + np.uint64(1)
        self._codes_sorted = None
        self._order = None
        self._n_indexed = 0

    def _codes(self, indptr: np.ndarray, neighbors: np.ndarray,
               query: bool) -> np.ndarray:
        """int64[n_bands, n] band codes. Empty neighborhoods get codes
        outside the 2^rows range and DISJOINT between fit (positive) and
        query (negative) roles, so degree-0 nodes never collide with
        anything."""
        indptr = np.asarray(indptr, np.int64)
        n = indptr.size - 1
        e = int(indptr[-1])
        x = np.asarray(neighbors, np.uint64) + np.uint64(1)
        starts = np.minimum(indptr[:-1], max(e - 1, 0))
        empty = indptr[:-1] == indptr[1:]
        codes = np.zeros((self.n_bands, n), np.int64)
        ids = np.arange(n, dtype=np.int64)
        sentinel = (-ids - 1) if query else ((1 << self.rows_per_band) + ids)
        for b in range(self.n_bands):
            code = np.zeros(n, np.int64)
            for r in range(self.rows_per_band):
                a = self._mults[b * self.rows_per_band + r]
                mn = (np.minimum.reduceat(x * a, starts) if e
                      else np.zeros(n, np.uint64))
                bit = ((mn >> np.uint64(32)) & np.uint64(1)).astype(np.int64)
                code = (code << 1) | bit
            codes[b] = np.where(empty, sentinel, code)
        return codes

    def fit(self, indptr: np.ndarray, neighbors: np.ndarray) -> "MinHashIndex":
        codes = self._codes(indptr, neighbors, query=False)
        self._order = np.argsort(codes, axis=1, kind="stable")
        self._codes_sorted = np.take_along_axis(codes, self._order, axis=1)
        self._n_indexed = codes.shape[1]
        return self

    def query(self, indptr: np.ndarray, neighbors: np.ndarray):
        """Candidate indexed-node ids per query node.

        Returns (flat int64[C], indptr int64[n_q + 1]): node ids sorted
        ascending within each query's slice. One vectorized plan: per
        (query, band) bucket slice bounds by searchsorted, capped
        counts, then a single repeat/arange gather — the prefix-sum
        planning of the exemplar, without the per-query dict walk.
        """
        if self._codes_sorted is None:
            raise RuntimeError("fit() before query()")
        qc = self._codes(indptr, neighbors, query=True)
        n_q = qc.shape[1]
        lo = np.empty((self.n_bands, n_q), np.int64)
        hi = np.empty((self.n_bands, n_q), np.int64)
        for b in range(self.n_bands):
            lo[b] = np.searchsorted(self._codes_sorted[b], qc[b], "left")
            hi[b] = np.searchsorted(self._codes_sorted[b], qc[b], "right")
        cnt = np.minimum(hi - lo, self.max_per_band)
        # plan: flatten (band, query) slots, prefix-sum the capped
        # counts, expand to per-candidate (slot, within-bucket offset)
        flat_cnt = cnt.ravel()
        offs = np.concatenate([np.zeros(1, np.int64),
                               np.cumsum(flat_cnt)])
        total = int(offs[-1])
        slot = np.repeat(np.arange(flat_cnt.size), flat_cnt)
        within = np.arange(total, dtype=np.int64) - offs[slot]
        src = lo.ravel()[slot] + within
        band_of = slot // n_q
        q_of = slot % n_q
        nodes = self._order[band_of, src] if total else np.empty(0, np.int64)
        return _csr_unique_pairs(q_of, nodes, n_q, self._n_indexed)

    def candidate_labels(self, indptr: np.ndarray, neighbors: np.ndarray,
                         labels_of_indexed: np.ndarray, n_labels: int):
        """Candidate LABELS per query node: the labels carried by each
        query's bucket-mates, deduped and sorted per query — exactly the
        (flat, indptr) contract of ``lp_cold_assign(cand_labels=...)``.
        """
        nodes, iptr = self.query(indptr, neighbors)
        q_of = np.repeat(np.arange(iptr.size - 1, dtype=np.int64),
                         np.diff(iptr))
        lab = np.asarray(labels_of_indexed, np.int64)[nodes]
        return _csr_unique_pairs(q_of, lab, iptr.size - 1, n_labels)


def _side_candidates(indptr, neigh, warm_end, labels_side, opp_labels,
                     n_labels, neighbor_cap, **kw):
    """One side's cold candidate sets: fit the minhash index on the warm
    prefix of the side's CSR, query the cold tail, and union in the
    labels of up to ``neighbor_cap`` of each cold node's own neighbors.

    The neighbor nomination closes the structural hole a same-side
    index cannot: a label carried by NO warm same-side node (e.g. a
    lone opposite-side singleton the cold node should join) is
    invisible to bucket-mates, but the exact argmax is by definition a
    neighbor label — so for nodes with degree <= neighbor_cap the union
    is exhaustive (recall 1 by construction) and head nodes stay capped
    at O(n_bands * max_per_band + neighbor_cap) candidates, independent
    of the label-universe size."""
    indptr = np.asarray(indptr, np.int64)
    cut = int(indptr[warm_end])
    idx = MinHashIndex(**kw).fit(indptr[:warm_end + 1], neigh[:cut])
    q_iptr = indptr[warm_end:] - cut
    q_neigh = neigh[cut:]
    nodes, niptr = idx.query(q_iptr, q_neigh)
    q_of = np.repeat(np.arange(niptr.size - 1, dtype=np.int64),
                     np.diff(niptr))
    lab = np.asarray(labels_side, np.int64)[:warm_end][nodes]
    n_q = q_iptr.size - 1
    if neighbor_cap > 0:
        deg = np.diff(q_iptr)
        take = np.minimum(deg, neighbor_cap)
        offs = np.concatenate([np.zeros(1, np.int64), np.cumsum(take)])
        q2 = np.repeat(np.arange(n_q, dtype=np.int64), take)
        within = np.arange(int(offs[-1]), dtype=np.int64) - offs[q2]
        src = q_iptr[:-1][q2] + within
        lab2 = np.asarray(opp_labels, np.int64)[q_neigh[src]]
        q_of = np.concatenate([q_of, q2])
        lab = np.concatenate([lab, lab2])
    return _csr_unique_pairs(q_of, lab, n_q, n_labels)


def cold_candidate_sets(graph: BipartiteGraph, labels: np.ndarray,
                        n_new_users: int = 0, n_new_items: int = 0,
                        neighbor_cap: int = 32, **kw) -> dict:
    """The ``cand_labels`` dict for ``lp_cold_assign``: per cold node,
    the labels of warm same-side nodes with minhash-similar
    neighborhoods, unioned with up to ``neighbor_cap`` of the node's
    own neighbors' labels. Cold nodes are index suffixes of their sides
    (the stream layer's growth contract); the index is fit over the
    warm prefix only, so a cold node can never nominate another cold
    node's fresh singleton."""
    labels = np.asarray(labels, np.int64)
    nu, n = graph.n_users, graph.n_nodes
    out = {}
    if n_new_users:
        iptr, neigh = graph.user_csr()
        out["user"] = _side_candidates(iptr, neigh, nu - n_new_users,
                                       labels[:nu], labels[nu:], n,
                                       neighbor_cap, **kw)
    if n_new_items:
        iptr, neigh = graph.item_csr()
        out["item"] = _side_candidates(iptr, neigh,
                                       graph.n_items - n_new_items,
                                       labels[nu:], labels[:nu], n,
                                       neighbor_cap, **kw)
    return out


def prune_graph(graph: BipartiteGraph, labels: np.ndarray, **kw):
    """Warm-refresh pruning: drop edges whose candidate label neither
    side's minhash candidate set (nor the own-label edge set) contains,
    so a full refresh sweep scores O(bucket) labels per node.

    Each side is indexed AND queried over itself (self-buckets keep a
    node's own cluster reachable). Returns (pruned_graph, kept_frac);
    the pruned graph is approximate by construction — the engine knob
    keeps exact as default and the bench measures the quality delta.
    """
    labels = np.asarray(labels, np.int64)
    nu, n = graph.n_users, graph.n_nodes
    lab_u, lab_v = labels[:nu], labels[nu:]

    def side_keep(indptr, neigh, labels_side, opp_lab_of_edge, node_of_edge):
        idx = MinHashIndex(**kw).fit(indptr, neigh)
        flat, iptr = idx.candidate_labels(indptr, neigh, labels_side, n)
        m = np.int64(n) + 1
        reps = np.diff(iptr)
        ckeys = np.repeat(np.arange(reps.size, dtype=np.int64),
                          reps) * m + flat
        keys = node_of_edge.astype(np.int64) * m \
            + opp_lab_of_edge.astype(np.int64)
        if ckeys.size == 0:
            return np.zeros(keys.shape, bool)
        pos = np.minimum(np.searchsorted(ckeys, keys), ckeys.size - 1)
        return ckeys[pos] == keys

    u_iptr, u_neigh = graph.user_csr()
    v_iptr, v_neigh = graph.item_csr()
    keep = side_keep(u_iptr, u_neigh, lab_u, lab_v[graph.edge_v],
                     graph.edge_u)
    keep_v = side_keep(v_iptr, v_neigh, lab_v, lab_u[graph.edge_u[
        graph.perm_by_item]], graph.edge_v[graph.perm_by_item])
    inv = np.empty_like(graph.perm_by_item)
    inv[graph.perm_by_item] = np.arange(graph.perm_by_item.size,
                                        dtype=graph.perm_by_item.dtype)
    keep |= keep_v[inv]
    keep |= lab_u[graph.edge_u] == lab_v[graph.edge_v]   # own-cluster edges
    pruned = BipartiteGraph.from_edges(
        graph.n_users, graph.n_items, graph.edge_u[keep],
        graph.edge_v[keep], dedup=False)
    return pruned, float(keep.mean()) if keep.size else 1.0


def candidate_recall(cand: tuple, chosen_labels: np.ndarray,
                     own_labels: np.ndarray) -> float:
    """Fraction of nodes whose exact-assignment choice survives pruning:
    the chosen label is the node's own (kept singleton — always a
    candidate) or is in its candidate set. THE acceptance metric for
    ``candidates="minhash"``."""
    flat, iptr = cand
    chosen = np.asarray(chosen_labels, np.int64)
    own = np.asarray(own_labels, np.int64)
    n_q = iptr.size - 1
    if chosen.size != n_q or own.size != n_q:
        raise ValueError("chosen/own must have one entry per query node")
    hit = chosen == own
    if flat.size:
        m = np.int64(flat.max() if flat.size else 0) + chosen.max() + 2
        reps = np.diff(iptr)
        ckeys = np.repeat(np.arange(n_q, dtype=np.int64), reps) * m + flat
        keys = np.arange(n_q, dtype=np.int64) * m + chosen
        pos = np.minimum(np.searchsorted(ckeys, keys), ckeys.size - 1)
        hit |= ckeys[pos] == keys
    return float(hit.mean()) if n_q else 1.0
