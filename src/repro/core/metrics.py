"""Clustering quality metrics used throughout the paper's experiments.

All metrics operate on labels in the shared id space (or per-side label
arrays) and the BipartiteGraph; pure numpy — these run host-side on
preprocessing outputs.
"""
from __future__ import annotations

import numpy as np

from .graph import BipartiteGraph

__all__ = [
    "objective", "intra_edges", "gini", "accl",
    "bipartite_modularity", "bipartite_cpm", "cluster_sizes",
]


def _side_labels(graph: BipartiteGraph, labels: np.ndarray):
    return labels[:graph.n_users], labels[graph.n_users:]


def intra_edges(graph: BipartiteGraph, labels: np.ndarray) -> int:
    """Number of edges whose endpoints share a cluster (Σ_k s_k)."""
    lu, lv = _side_labels(graph, labels)
    return int(np.sum(lu[graph.edge_u] == lv[graph.edge_v]))


def objective(graph: BipartiteGraph, labels: np.ndarray, w_users, w_items,
              gamma: float) -> float:
    """Eq. (9): Σ_k s_k − γ Σ_k W_u(k)·W_v(k) (cross-pair volume form)."""
    lu, lv = _side_labels(graph, labels)
    n = graph.n_nodes
    wu_k = np.bincount(lu, weights=w_users, minlength=n)
    wv_k = np.bincount(lv, weights=w_items, minlength=n)
    return intra_edges(graph, labels) - gamma * float(wu_k @ wv_k)


def cluster_sizes(labels: np.ndarray) -> np.ndarray:
    """Sizes of the distinct clusters present in `labels` (any id space)."""
    _, cnt = np.unique(labels, return_counts=True)
    return cnt


def gini(sizes: np.ndarray) -> float:
    """Gini coefficient of cluster sizes (0 = perfectly balanced)."""
    s = np.sort(np.asarray(sizes, dtype=np.float64))
    k = s.size
    if k == 0 or s.sum() == 0:
        return 0.0
    cum = np.cumsum(s)
    # paper's form: (2/K) Σ_i (i/K − cum_i/total)
    i = np.arange(1, k + 1)
    return float((2.0 / k) * np.sum(i / k - cum / cum[-1]))


def accl(graph: BipartiteGraph, labels: np.ndarray) -> float:
    """Averaged cross-cluster links: inter-cluster edges / C(K,2)."""
    lu, lv = _side_labels(graph, labels)
    inter = graph.n_edges - intra_edges(graph, labels)
    k = np.unique(labels).size
    pairs = k * (k - 1) / 2.0
    return float(inter / pairs) if pairs > 0 else 0.0


def bipartite_modularity(graph: BipartiteGraph, labels: np.ndarray,
                         gamma: float = 1.0) -> float:
    """Barber's bipartite modularity, Eq. (1)."""
    lu, lv = _side_labels(graph, labels)
    e = max(graph.n_edges, 1)
    n = graph.n_nodes
    du_k = np.bincount(lu, weights=graph.user_degrees().astype(np.float64),
                       minlength=n)
    dv_k = np.bincount(lv, weights=graph.item_degrees().astype(np.float64),
                       minlength=n)
    return (intra_edges(graph, labels) - gamma * float(du_k @ dv_k) / e) / e


def bipartite_cpm(graph: BipartiteGraph, labels: np.ndarray,
                  gamma: float = 1.0) -> float:
    """Bipartite Constant Potts Model: Σ_k s_k − γ|U_k||V_k|."""
    lu, lv = _side_labels(graph, labels)
    n = graph.n_nodes
    nu_k = np.bincount(lu, minlength=n).astype(np.float64)
    nv_k = np.bincount(lv, minlength=n).astype(np.float64)
    return intra_edges(graph, labels) - gamma * float(nu_k @ nv_k)
