"""Sketch container: the output of every ETC method in this framework.

A Sketch is the frozen pre-training compression artifact: integer index
arrays mapping each user/item to codebook rows. Multi-hot sketches
(SCU, double hashing, compositional embeddings) carry up to
``n_hot`` indices per entity; lookup combines the rows by summation
(paper §4.5 / §3.3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Sketch", "compact_labels"]


def compact_labels(labels: np.ndarray, *extra: np.ndarray):
    """Map arbitrary int labels (shared id space) to consecutive ints.

    Returns (K, mapped, *mapped_extra): the joint label universe of
    ``labels`` and every array in ``extra`` is compacted together so
    primary and secondary assignments index one codebook.
    """
    allv = np.concatenate([labels] + list(extra)) if extra else labels
    uniq, inv = np.unique(allv, return_inverse=True)
    out = []
    off = 0
    for arr in [labels] + list(extra):
        out.append(inv[off:off + arr.shape[0]].astype(np.int32))
        off += arr.shape[0]
    return (int(uniq.shape[0]), *out)


@dataclasses.dataclass(frozen=True)
class Sketch:
    """Compression mapping for one user table and one item table.

    user_idx: int32[|U|, H_u]  codebook row(s) per user (H_u-hot)
    item_idx: int32[|V|, H_v]  codebook row(s) per item
    k_users:  number of user codebook rows
    k_items:  number of item codebook rows
    """

    user_idx: np.ndarray
    item_idx: np.ndarray
    k_users: int
    k_items: int
    method: str = "unknown"
    meta: Optional[dict] = None

    def __post_init__(self):
        for name, arr, k in (("user_idx", self.user_idx, self.k_users),
                             ("item_idx", self.item_idx, self.k_items)):
            if arr.ndim != 2:
                raise ValueError(f"{name} must be [N, H]-shaped, got {arr.shape}")
            if arr.size and (arr.min() < 0 or arr.max() >= k):
                raise ValueError(f"{name} out of codebook range [0,{k})")

    # -- sizes -------------------------------------------------------------
    @property
    def n_users(self) -> int:
        return int(self.user_idx.shape[0])

    @property
    def n_items(self) -> int:
        return int(self.item_idx.shape[0])

    def n_params(self, d: int) -> int:
        """Trainable embedding parameters under this sketch."""
        return (self.k_users + self.k_items) * d

    def compression_ratio(self, d: int) -> float:
        full = (self.n_users + self.n_items) * d
        return self.n_params(d) / max(full, 1)

    # -- serialization (serve/artifact.py bundles) --------------------------
    def state_arrays(self) -> dict:
        """The index arrays that define this sketch (deployable state)."""
        return {"user_idx": self.user_idx, "item_idx": self.item_idx}

    def meta_json(self) -> dict:
        """JSON-safe provenance: method + every scalar meta entry.
        Array-valued entries (e.g. the pre-compaction joint labels) stay
        out of the manifest — they are solver intermediates, not state."""
        out = {"method": self.method}
        for k, v in (self.meta or {}).items():
            if isinstance(v, (bool, int, float, str)) or v is None:
                out[k] = v
            elif isinstance(v, np.integer):
                out[k] = int(v)
            elif isinstance(v, np.floating):
                out[k] = float(v)
        return out

    @staticmethod
    def from_state(arrays: dict, k_users: int, k_items: int,
                   method: str = "unknown",
                   meta: Optional[dict] = None) -> "Sketch":
        """Rebuild a Sketch from `state_arrays` output (validates ranges)."""
        return Sketch(np.asarray(arrays["user_idx"], np.int32),
                      np.asarray(arrays["item_idx"], np.int32),
                      int(k_users), int(k_items), method=method, meta=meta)

    # -- dense views (tests / small graphs) ---------------------------------
    def dense_Y_user(self) -> np.ndarray:
        y = np.zeros((self.n_users, self.k_users), dtype=np.float32)
        for h in range(self.user_idx.shape[1]):
            y[np.arange(self.n_users), self.user_idx[:, h]] = 1.0
        return y

    def dense_Y_item(self) -> np.ndarray:
        y = np.zeros((self.n_items, self.k_items), dtype=np.float32)
        for h in range(self.item_idx.shape[1]):
            y[np.arange(self.n_items), self.item_idx[:, h]] = 1.0
        return y

    @staticmethod
    def one_hot(user_labels: np.ndarray, item_labels: np.ndarray,
                method: str = "unknown", meta: Optional[dict] = None) -> "Sketch":
        """Build a 1-hot sketch from per-side label arrays (auto-compacted)."""
        ku, ul = compact_labels(np.asarray(user_labels))
        kv, il = compact_labels(np.asarray(item_labels))
        return Sketch(ul[:, None], il[:, None], ku, kv, method=method, meta=meta)
