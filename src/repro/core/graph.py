"""Bipartite interaction graph: the substrate BACO clusters over.

Stored as an edge list with both CSR orderings precomputed so the
side-synchronous LP solver can run gather/segment passes without
re-sorting. Host-side state is numpy; solvers move what they need to
device.

Derived views (degrees, CSR index pointers) are memoized on the graph:
the numpy solver and the SCU pass hit ``user_csr()``/``item_csr()`` in
hot loops and the arrays are immutable, so they are computed once.
Million-edge graphs are built with ``from_edge_blocks`` (or
``from_edges(chunk_size=...)``), which dedups/sorts fixed-size edge
blocks and merges the sorted key runs instead of materializing the full
int64 key array plus its sorted copy at once.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

import numpy as np

__all__ = ["BipartiteGraph", "pad_rung", "node_aligned_bounds"]


def pad_rung(n: int, floor: int = 8) -> int:
    """Next power-of-two >= n (>= floor): THE capacity-ladder rung used
    everywhere shapes must stay stable while data grows — the padded
    solver/cold-assign programs (core.solver_jax), the swap-capable
    serving session (repro.serve), and the stream fine-tuner. One
    definition, so the "one compiled program" invariants on every side
    agree about where the rungs sit."""
    n = max(int(n), 1)
    return max(int(floor), 1 << (n - 1).bit_length())


def node_aligned_bounds(indptr: np.ndarray, block_edges: int) -> np.ndarray:
    """Edge-block boundaries of at most ``block_edges`` edges each, cut
    at node boundaries of a node-sorted edge list.

    ``indptr`` is the CSR index pointer of the updating side (node i's
    edges occupy ``[indptr[i], indptr[i+1])``). Every returned boundary
    is some ``indptr[k]``, so no node's edge run ever straddles a block
    — the streamed LP half-step's per-(node, label) groups stay
    block-local and the accumulate-then-commit sweep is bit-for-bit
    equal to the in-memory one. A single node whose run exceeds
    ``block_edges`` gets its own oversized block (the device program is
    padded to the max block length, so shapes stay fixed).

    THE shared blocking primitive: the streamed solver's sweep plan and
    ``distributed.sharding.edge_partition(bounds=...)`` both consume
    these offsets, so per-device shards and per-dispatch blocks agree
    about where a node's edges may be split (nowhere).
    """
    indptr = np.asarray(indptr, np.int64)
    e = int(indptr[-1])
    if block_edges <= 0:
        raise ValueError("block_edges must be positive")
    if e == 0:
        return np.zeros(1, np.int64)
    bounds = [0]
    pos = 0
    while pos < e:
        target = pos + int(block_edges)
        if target >= e:
            bounds.append(e)
            break
        # node owning edge index ``target``; its run start is the last
        # node boundary <= target
        nd = int(np.searchsorted(indptr, target, side="right")) - 1
        cut = int(indptr[nd])
        if cut <= pos:                       # one node's run > block_edges
            cut = int(indptr[nd + 1])
        bounds.append(cut)
        pos = cut
    return np.asarray(bounds, np.int64)


def _block_keys(n_users: int, n_items: int, edge_u, edge_v) -> np.ndarray:
    """Validated, deduped, sorted int64 keys u*n_items+v for one block."""
    eu = np.asarray(edge_u, dtype=np.int64)
    ev = np.asarray(edge_v, dtype=np.int64)
    if eu.shape != ev.shape or eu.ndim != 1:
        raise ValueError("edge_u/edge_v must be 1-D and equal length")
    if eu.size and (eu.min() < 0 or eu.max() >= n_users):
        raise ValueError("user index out of range")
    if ev.size and (ev.min() < 0 or ev.max() >= n_items):
        raise ValueError("item index out of range")
    return np.unique(eu * n_items + ev)


def _fresh_mask(a: np.ndarray, b: np.ndarray,
                ins: np.ndarray) -> np.ndarray:
    """Which entries of sorted-unique ``b`` are absent from sorted-
    unique ``a``, given ``ins = searchsorted(a, b)``."""
    if a.size == 0:
        return np.ones(b.shape, dtype=bool)
    return (ins == a.size) | (a[np.minimum(ins, a.size - 1)] != b)


def _merge_disjoint(a: np.ndarray, b: np.ndarray,
                    ins: np.ndarray) -> np.ndarray:
    """Merge sorted run ``a`` with sorted ``b`` DISJOINT from it, given
    ``ins = searchsorted(a, b)`` — one pass, no re-search."""
    out = np.empty(a.size + b.size, dtype=a.dtype if a.size else b.dtype)
    pos = ins + np.arange(b.size)
    mask = np.zeros(out.size, dtype=bool)
    mask[pos] = True
    out[mask] = b
    out[~mask] = a
    return out


def _merge_unique(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Merge two SORTED UNIQUE int64 runs into one (no full re-sort:
    O(|a| + |b| log |a|) via searchsorted insertion positions)."""
    if a.size == 0:
        return b
    if b.size == 0:
        return a
    ins = np.searchsorted(a, b)
    fresh = _fresh_mask(a, b, ins)
    return _merge_disjoint(a, b[fresh], ins[fresh])


@dataclasses.dataclass(frozen=True)
class BipartiteGraph:
    """User-item interaction graph G = (U ∪ V, E).

    Attributes:
      n_users: |U|
      n_items: |V|
      edge_u:  int32[E] user endpoint of each edge, sorted by (u, v)
      edge_v:  int32[E] item endpoint of each edge, sorted by (u, v)
      perm_by_item: int32[E] permutation such that edge_v[perm_by_item]
        is sorted (CSR of the transposed bi-adjacency).
    """

    n_users: int
    n_items: int
    edge_u: np.ndarray
    edge_v: np.ndarray
    perm_by_item: np.ndarray
    # memo for derived views; arrays are immutable so entries never stale
    _cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                     compare=False)

    @staticmethod
    def from_edges(n_users: int, n_items: int, edge_u, edge_v,
                   dedup: bool = True,
                   chunk_size: Optional[int] = None) -> "BipartiteGraph":
        if chunk_size is not None:
            # no up-front int64 conversion of the full arrays — the
            # whole point of the chunked path is one block at a time
            if not dedup:
                raise ValueError("chunked build implies dedup")
            eu = np.asarray(edge_u)
            ev = np.asarray(edge_v)
            if eu.shape != ev.shape or eu.ndim != 1:
                raise ValueError("edge_u/edge_v must be 1-D and equal length")
            blocks = ((eu[i:i + chunk_size], ev[i:i + chunk_size])
                      for i in range(0, max(eu.size, 1), chunk_size))
            return BipartiteGraph.from_edge_blocks(n_users, n_items, blocks)
        edge_u = np.asarray(edge_u, dtype=np.int64)
        edge_v = np.asarray(edge_v, dtype=np.int64)
        if edge_u.shape != edge_v.shape or edge_u.ndim != 1:
            raise ValueError("edge_u/edge_v must be 1-D and equal length")
        if edge_u.size and (edge_u.min() < 0 or edge_u.max() >= n_users):
            raise ValueError("user index out of range")
        if edge_v.size and (edge_v.min() < 0 or edge_v.max() >= n_items):
            raise ValueError("item index out of range")
        key = edge_u * n_items + edge_v
        if dedup:
            key = np.unique(key)
        else:
            key = np.sort(key)
        return BipartiteGraph._from_sorted_keys(n_users, n_items, key)

    @staticmethod
    def from_edge_blocks(n_users: int, n_items: int,
                         blocks: Iterable[Tuple[np.ndarray, np.ndarray]],
                         ) -> "BipartiteGraph":
        """Streaming builder: ``blocks`` yields (edge_u, edge_v) chunks.

        Each block is validated/deduped/sorted on its own, then merged
        into the accumulated sorted unique-key run with a searchsorted
        run-merge (no full re-sort per block) — peak memory is two
        copies of the DEDUPED key run plus one block; the raw int64 key
        array and its full sorted copy never coexist.
        """
        acc = np.empty(0, dtype=np.int64)
        for bu, bv in blocks:
            acc = _merge_unique(acc, _block_keys(n_users, n_items, bu, bv))
        return BipartiteGraph._from_sorted_keys(n_users, n_items, acc)

    @staticmethod
    def _from_sorted_keys(n_users: int, n_items: int,
                          key: np.ndarray) -> "BipartiteGraph":
        eu = (key // n_items).astype(np.int32)
        ev = (key % n_items).astype(np.int32)
        perm = np.argsort(ev, kind="stable").astype(np.int32)
        return BipartiteGraph(int(n_users), int(n_items), eu, ev, perm)

    def _memo(self, name: str, fn):
        if name not in self._cache:
            self._cache[name] = fn()
        return self._cache[name]

    # -- basic stats -------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.edge_u.shape[0])

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_items

    def user_degrees(self) -> np.ndarray:
        return self._memo("user_deg", lambda: np.bincount(
            self.edge_u, minlength=self.n_users).astype(np.int64))

    def item_degrees(self) -> np.ndarray:
        return self._memo("item_deg", lambda: np.bincount(
            self.edge_v, minlength=self.n_items).astype(np.int64))

    def density(self) -> float:
        return self.n_edges / float(max(1, self.n_users) * max(1, self.n_items))

    # -- adjacency views ---------------------------------------------------
    def user_csr(self):
        """(indptr, item_indices) neighbor lists per user. Memoized."""
        def build():
            indptr = np.zeros(self.n_users + 1, dtype=np.int64)
            np.cumsum(self.user_degrees(), out=indptr[1:])
            return indptr, self.edge_v
        return self._memo("user_csr", build)

    def item_csr(self):
        """(indptr, user_indices) neighbor lists per item. Memoized."""
        def build():
            indptr = np.zeros(self.n_items + 1, dtype=np.int64)
            np.cumsum(self.item_degrees(), out=indptr[1:])
            return indptr, self.edge_u[self.perm_by_item]
        return self._memo("item_csr", build)

    def edges_by_item(self):
        """(edge_v_sorted, edge_u_by_item): both endpoint arrays in the
        by-item ordering (the item half-step's orientation). Memoized —
        the streamed solver and cold-assign hit this once per solve."""
        return self._memo("edges_by_item", lambda: (
            self.edge_v[self.perm_by_item], self.edge_u[self.perm_by_item]))

    def edge_block_bounds(self, side: str, block_edges: int) -> np.ndarray:
        """Node-aligned edge-block offsets for one side's sorted edge
        orientation (``node_aligned_bounds`` over that side's CSR
        indptr). side: "user" | "item". Memoized per (side, size)."""
        if side not in ("user", "item"):
            raise ValueError(f"side must be 'user'|'item', got {side!r}")
        indptr = (self.user_csr() if side == "user" else self.item_csr())[0]
        return self._memo(f"blocks/{side}/{int(block_edges)}",
                          lambda: node_aligned_bounds(indptr, block_edges))

    def biadjacency(self) -> np.ndarray:
        """Dense {0,1} bi-adjacency B (tests / tiny graphs only)."""
        b = np.zeros((self.n_users, self.n_items), dtype=np.float32)
        b[self.edge_u, self.edge_v] = 1.0
        return b
