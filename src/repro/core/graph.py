"""Bipartite interaction graph: the substrate BACO clusters over.

Stored as an edge list with both CSR orderings precomputed so the
side-synchronous LP solver can run gather/segment passes without
re-sorting. Host-side state is numpy; solvers move what they need to
device.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["BipartiteGraph"]


@dataclasses.dataclass(frozen=True)
class BipartiteGraph:
    """User-item interaction graph G = (U ∪ V, E).

    Attributes:
      n_users: |U|
      n_items: |V|
      edge_u:  int32[E] user endpoint of each edge, sorted by (u, v)
      edge_v:  int32[E] item endpoint of each edge, sorted by (u, v)
      perm_by_item: int32[E] permutation such that edge_v[perm_by_item]
        is sorted (CSR of the transposed bi-adjacency).
    """

    n_users: int
    n_items: int
    edge_u: np.ndarray
    edge_v: np.ndarray
    perm_by_item: np.ndarray

    @staticmethod
    def from_edges(n_users: int, n_items: int, edge_u, edge_v,
                   dedup: bool = True) -> "BipartiteGraph":
        edge_u = np.asarray(edge_u, dtype=np.int64)
        edge_v = np.asarray(edge_v, dtype=np.int64)
        if edge_u.shape != edge_v.shape or edge_u.ndim != 1:
            raise ValueError("edge_u/edge_v must be 1-D and equal length")
        if edge_u.size and (edge_u.min() < 0 or edge_u.max() >= n_users):
            raise ValueError("user index out of range")
        if edge_v.size and (edge_v.min() < 0 or edge_v.max() >= n_items):
            raise ValueError("item index out of range")
        key = edge_u * n_items + edge_v
        if dedup:
            key = np.unique(key)
        else:
            key = np.sort(key)
        eu = (key // n_items).astype(np.int32)
        ev = (key % n_items).astype(np.int32)
        perm = np.argsort(ev, kind="stable").astype(np.int32)
        return BipartiteGraph(int(n_users), int(n_items), eu, ev, perm)

    # -- basic stats -------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.edge_u.shape[0])

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_items

    def user_degrees(self) -> np.ndarray:
        return np.bincount(self.edge_u, minlength=self.n_users).astype(np.int64)

    def item_degrees(self) -> np.ndarray:
        return np.bincount(self.edge_v, minlength=self.n_items).astype(np.int64)

    def density(self) -> float:
        return self.n_edges / float(max(1, self.n_users) * max(1, self.n_items))

    # -- adjacency views ---------------------------------------------------
    def user_csr(self):
        """(indptr, item_indices) neighbor lists per user."""
        deg = self.user_degrees()
        indptr = np.zeros(self.n_users + 1, dtype=np.int64)
        np.cumsum(deg, out=indptr[1:])
        return indptr, self.edge_v

    def item_csr(self):
        """(indptr, user_indices) neighbor lists per item."""
        deg = self.item_degrees()
        indptr = np.zeros(self.n_items + 1, dtype=np.int64)
        np.cumsum(deg, out=indptr[1:])
        return indptr, self.edge_u[self.perm_by_item]

    def biadjacency(self) -> np.ndarray:
        """Dense {0,1} bi-adjacency B (tests / tiny graphs only)."""
        b = np.zeros((self.n_users, self.n_items), dtype=np.float32)
        b[self.edge_u, self.edge_v] = 1.0
        return b
