# Ensures the repo root (for `import benchmarks`) is importable when
# pytest runs with only PYTHONPATH=src.
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
