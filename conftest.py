# Ensures the repo root (for `import benchmarks`) is importable when
# pytest runs with only PYTHONPATH=src.
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running integration test (subprocess compiles on a "
        "512-device host mesh); deselect with -m 'not slow'")
